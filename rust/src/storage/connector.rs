//! Connectors: the brokers between worker nodes and the DBMS.
//!
//! Paper §3.1: "*Connectors* are brokers that intermediate the communication
//! between the DBMS and other components... If a connector fails, all worker
//! nodes connected to it are switched to their secondary ones." and the
//! distribution rule: a worker co-located with a connector uses it as
//! primary; remaining workers are assigned round-robin.

use crate::storage::cluster::DbCluster;
use crate::storage::prepared::Prepared;
use crate::storage::stats::AccessKind;
use crate::storage::value::Value;
use crate::storage::StatementResult;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// A connector (DBMS driver endpoint). Carries an `alive` flag for failure
/// injection and counts the statements it brokered.
pub struct Connector {
    pub id: u32,
    /// Physical node hosting this connector (for co-location assignment).
    pub physical_node: u32,
    cluster: Arc<DbCluster>,
    alive: AtomicBool,
    pub brokered: AtomicU64,
}

impl Connector {
    pub fn new(id: u32, physical_node: u32, cluster: Arc<DbCluster>) -> Arc<Connector> {
        Arc::new(Connector {
            id,
            physical_node,
            cluster,
            alive: AtomicBool::new(true),
            brokered: AtomicU64::new(0),
        })
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// The cluster this connector brokers for. Chaos drivers and the
    /// availability machinery reach through here to inject data-node
    /// failures (`kill_node`) and drive recovery (`restart_node`,
    /// availability sweeps) on the same cluster the workers are using.
    pub fn cluster(&self) -> &Arc<DbCluster> {
        &self.cluster
    }

    pub fn kill(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }

    pub fn revive(&self) {
        self.alive.store(true, Ordering::SeqCst);
    }

    /// Broker one statement for a worker node.
    pub fn exec(&self, worker_node: u32, kind: AccessKind, sql: &str) -> Result<StatementResult> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_tagged(worker_node, kind, sql)
    }

    /// Broker a pre-parsed statement (hot path).
    pub fn exec_stmt(
        &self,
        worker_node: u32,
        kind: AccessKind,
        stmt: &crate::storage::sql::Statement,
    ) -> Result<StatementResult> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_stmt(worker_node, kind, stmt)
    }

    /// Prepare a statement through this connector. The handle it returns is
    /// plan-only (no connection state), so it remains valid on the sibling
    /// connectors of the same cluster — the basis of prepared failover.
    pub fn prepare(&self, sql: &str) -> Result<Prepared> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.cluster.prepare(sql)
    }

    /// Broker one prepared execution (compiled fast path when the plan
    /// classified as a fast shape, interpreted otherwise).
    pub fn exec_prepared(
        &self,
        worker_node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_prepared(worker_node, kind, prepared, params)
    }

    /// Broker one prepared execution through the interpreted reference
    /// path, bypassing the compiled fast path (differential testing).
    pub fn exec_prepared_interpreted(
        &self,
        worker_node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_prepared_interpreted(worker_node, kind, prepared, params)
    }

    /// Broker one prepared batched insert.
    pub fn exec_prepared_batch(
        &self,
        worker_node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_prepared_batch(worker_node, kind, prepared, rows)
    }

    /// Broker one atomic statement batch (union 2PL lock set).
    pub fn exec_txn(
        &self,
        worker_node: u32,
        kind: AccessKind,
        stmts: &[crate::storage::sql::Statement],
    ) -> Result<Vec<StatementResult>> {
        if !self.is_alive() {
            return Err(Error::Unavailable(format!("connector {} is down", self.id)));
        }
        self.brokered.fetch_add(1, Ordering::Relaxed);
        self.cluster.exec_txn(worker_node, kind, stmts)
    }
}

/// A worker's view of the connector fabric: a primary link and a secondary
/// to fail over to (paper Figure 2: full vs dashed gray lines).
pub struct WorkerLink {
    pub worker_node: u32,
    pub primary: Arc<Connector>,
    pub secondary: Option<Arc<Connector>>,
}

impl WorkerLink {
    /// Execute with failover: try primary, fall back to secondary if the
    /// primary connector is down.
    pub fn exec(&self, kind: AccessKind, sql: &str) -> Result<StatementResult> {
        match self.primary.exec(self.worker_node, kind, sql) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => {
                self.secondary.as_ref().unwrap().exec(self.worker_node, kind, sql)
            }
            other => other,
        }
    }

    /// Pre-parsed variant of [`WorkerLink::exec`].
    pub fn exec_stmt(
        &self,
        kind: AccessKind,
        stmt: &crate::storage::sql::Statement,
    ) -> Result<StatementResult> {
        match self.primary.exec_stmt(self.worker_node, kind, stmt) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => {
                self.secondary.as_ref().unwrap().exec_stmt(self.worker_node, kind, stmt)
            }
            other => other,
        }
    }

    /// Prepare through the active connector (failover like `exec`). The
    /// returned handle is shared-plan only, so it keeps executing through
    /// whichever connector is alive at each call.
    pub fn prepare(&self, sql: &str) -> Result<Prepared> {
        match self.primary.prepare(sql) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => {
                self.secondary.as_ref().unwrap().prepare(sql)
            }
            other => other,
        }
    }

    /// Prepared variant of [`WorkerLink::exec`]: primary first, secondary on
    /// connector outage — the same handle works on both.
    pub fn exec_prepared(
        &self,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        match self.primary.exec_prepared(self.worker_node, kind, prepared, params) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => self
                .secondary
                .as_ref()
                .unwrap()
                .exec_prepared(self.worker_node, kind, prepared, params),
            other => other,
        }
    }

    /// Interpreted-reference variant of [`WorkerLink::exec_prepared`]
    /// (differential testing of the compiled fast path under failover).
    pub fn exec_prepared_interpreted(
        &self,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        match self.primary.exec_prepared_interpreted(self.worker_node, kind, prepared, params) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => self
                .secondary
                .as_ref()
                .unwrap()
                .exec_prepared_interpreted(self.worker_node, kind, prepared, params),
            other => other,
        }
    }

    /// Prepared batched-insert variant of [`WorkerLink::exec`].
    pub fn exec_prepared_batch(
        &self,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        match self.primary.exec_prepared_batch(self.worker_node, kind, prepared, rows) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => self
                .secondary
                .as_ref()
                .unwrap()
                .exec_prepared_batch(self.worker_node, kind, prepared, rows),
            other => other,
        }
    }

    /// Atomic-batch variant of [`WorkerLink::exec`]: primary first,
    /// secondary on connector outage. The batch either commits through
    /// whichever connector brokered it or not at all — failover between
    /// the attempts cannot half-apply it, because nothing is applied
    /// until the brokered `exec_txn` commits.
    pub fn exec_txn(
        &self,
        kind: AccessKind,
        stmts: &[crate::storage::sql::Statement],
    ) -> Result<Vec<StatementResult>> {
        match self.primary.exec_txn(self.worker_node, kind, stmts) {
            Err(Error::Unavailable(_)) if self.secondary.is_some() => {
                self.secondary.as_ref().unwrap().exec_txn(self.worker_node, kind, stmts)
            }
            other => other,
        }
    }

    /// The cluster behind this link (either connector brokers the same
    /// one).
    pub fn cluster(&self) -> &Arc<DbCluster> {
        self.primary.cluster()
    }

    /// Which connector would serve right now (monitoring).
    pub fn active_connector(&self) -> u32 {
        if self.primary.is_alive() {
            self.primary.id
        } else if let Some(s) = &self.secondary {
            s.id
        } else {
            self.primary.id
        }
    }
}

/// Assign workers to connectors per the paper's strategy:
/// 1. a worker sharing a physical node with a connector gets it as primary;
/// 2. remaining workers are distributed round-robin;
/// 3. the secondary is the next connector in ring order (never the primary).
pub fn assign_links(
    worker_nodes: &[u32],
    connectors: &[Arc<Connector>],
) -> Result<Vec<WorkerLink>> {
    if connectors.is_empty() {
        return Err(Error::Catalog("need at least one connector".into()));
    }
    let mut links = Vec::with_capacity(worker_nodes.len());
    let mut rr = 0usize;
    for &w in worker_nodes {
        let co_located = connectors.iter().position(|c| c.physical_node == w);
        let pi = match co_located {
            Some(i) => i,
            None => {
                let i = rr % connectors.len();
                rr += 1;
                i
            }
        };
        let si = if connectors.len() > 1 { Some((pi + 1) % connectors.len()) } else { None };
        links.push(WorkerLink {
            worker_node: w,
            primary: connectors[pi].clone(),
            secondary: si.map(|i| connectors[i].clone()),
        });
    }
    Ok(links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::ClusterConfig;

    fn setup() -> (Arc<DbCluster>, Vec<Arc<Connector>>) {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec("CREATE TABLE t (id INT NOT NULL, v FLOAT) PRIMARY KEY (id)").unwrap();
        let conns = vec![
            Connector::new(0, 0, c.clone()),
            Connector::new(1, 1, c.clone()),
            Connector::new(2, 2, c.clone()),
        ];
        (c, conns)
    }

    #[test]
    fn colocated_worker_gets_local_connector() {
        let (_c, conns) = setup();
        let links = assign_links(&[0, 1, 5, 6], &conns).unwrap();
        assert_eq!(links[0].primary.id, 0); // worker 0 co-located with connector 0
        assert_eq!(links[1].primary.id, 1);
        // workers 5, 6 round-robin over connectors 0, 1
        assert_eq!(links[2].primary.id, 0);
        assert_eq!(links[3].primary.id, 1);
        // secondary is the ring successor, never the primary
        for l in &links {
            assert_ne!(l.primary.id, l.secondary.as_ref().unwrap().id);
        }
    }

    #[test]
    fn link_fails_over_to_secondary() {
        let (_c, conns) = setup();
        let links = assign_links(&[0], &conns).unwrap();
        let l = &links[0];
        l.exec(AccessKind::Other, "INSERT INTO t (id, v) VALUES (1, 1.0)").unwrap();
        assert_eq!(l.active_connector(), 0);
        conns[0].kill();
        assert_eq!(l.active_connector(), 1);
        // statement still succeeds through the secondary
        l.exec(AccessKind::Other, "INSERT INTO t (id, v) VALUES (2, 2.0)").unwrap();
        assert_eq!(conns[1].brokered.load(std::sync::atomic::Ordering::Relaxed), 1);
        conns[0].revive();
        l.exec(AccessKind::Other, "INSERT INTO t (id, v) VALUES (3, 3.0)").unwrap();
        assert_eq!(l.active_connector(), 0);
    }

    #[test]
    fn dead_connector_without_secondary_errors() {
        let (c, _) = setup();
        let only = Connector::new(9, 0, c);
        let links = assign_links(&[4], &[only.clone()]).unwrap();
        only.kill();
        let e = links[0].exec(AccessKind::Other, "SELECT * FROM t");
        assert!(matches!(e, Err(Error::Unavailable(_))));
    }

    #[test]
    fn no_connectors_is_an_error() {
        assert!(assign_links(&[0], &[]).is_err());
    }
}
