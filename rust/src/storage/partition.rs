//! A single table partition: a chunked copy-on-write slab of shared rows
//! plus hash indexes.
//!
//! Partitions are the unit of locking, replication, placement — and, since
//! the durability rework, of *logging*: every committed mutation carries
//! the partition's dense log sequence number (its `version` right after
//! the op applied), so a replica can be reconstructed from a checkpoint
//! plus a redo tail and then audited against the primary by LSN alone.
//!
//! Slot allocation is **canonical**: an insert always takes the smallest
//! free slot. That makes the slab layout a pure function of the committed
//! op history — two replicas that applied the same ops agree on every
//! future slot choice, which is what lets redo records address rows by
//! slot (and lets the chaos tests demand byte-equality between a rejoined
//! node and a never-killed twin).
//!
//! ## Snapshot representation (copy-on-write chunks)
//!
//! Rows are stored as `Arc<Row>` and grouped into fixed spans of
//! [`CHUNK_SLOTS`] slots. For each span the store keeps a **sealed**
//! immutable [`Chunk`] (shared via `Arc`) that it invalidates whenever a
//! slot inside the span mutates — that `None` entry *is* the per-chunk
//! dirty bit. [`PartitionStore::snapshot`] therefore costs an `Arc` bump
//! per clean chunk plus a re-seal of only the dirty ones (and re-sealing
//! is itself `Arc` bumps of the span's rows, never row deep-copies):
//! O(changed), where the previous representation deep-cloned every live
//! row under the partition read latch on every version change —
//! O(partition) paid by each steering read while 2PL writers stalled.
//!
//! Sealing a chunk also computes its **zone maps**: per numeric column,
//! the min/max over comparable non-NULL values plus a NULL count. The
//! scan engine uses them to skip whole chunks whose bounds cannot satisfy
//! a compiled WHERE conjunct ([`Chunk::may_match`]). Zone maps are
//! **conservative only**: they may fail to prune, never prune a chunk
//! that could match, and they are never consulted for point-read
//! correctness (index probes and the 2PL executors read the slab
//! directly).

use crate::storage::cexpr::Conjunct;
use crate::storage::sql::ast::Op;
use crate::storage::table_def::TableDef;
use crate::storage::value::{ColumnType, Row, Value};
use crate::storage::wal::{LogOp, WalRecord};
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::cmp::Ordering;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};

/// Slot handle inside a partition (stable until the row is deleted).
pub type Slot = usize;

/// Slots per copy-on-write chunk. Claim-loop point writes dirty one chunk;
/// a 100k-row partition re-seals 1 of ~400 chunks per steering snapshot.
pub const CHUNK_SLOTS: usize = 256;

/// Number of chunks covering a slab of `cap` slots.
fn chunk_count(cap: usize) -> usize {
    cap.div_ceil(CHUNK_SLOTS)
}

/// Zone map of one numeric column within one sealed chunk: bounds over the
/// values that can participate in a comparison, plus a NULL census.
#[derive(Clone, Debug)]
pub struct Zone {
    /// Smallest comparable value in the chunk (`Null` when `bounded == 0`).
    pub min: Value,
    /// Largest comparable value in the chunk (`Null` when `bounded == 0`).
    pub max: Value,
    /// NULL values seen (they never match a comparison).
    pub nulls: usize,
    /// Values inside `[min, max]` — non-NULL values that order under
    /// `sql_cmp`. NaN is excluded: it compares as `None` against
    /// everything, so it can never satisfy a conjunct and must not poison
    /// the bounds.
    pub bounded: usize,
}

impl Default for Zone {
    fn default() -> Zone {
        Zone { min: Value::Null, max: Value::Null, nulls: 0, bounded: 0 }
    }
}

impl Zone {
    fn fold(&mut self, v: &Value) {
        if v.is_null() {
            self.nulls += 1;
            return;
        }
        if v.sql_cmp(v).is_none() {
            // NaN: unordered under sql_cmp, never matches any conjunct
            return;
        }
        if self.bounded == 0 {
            self.min = v.clone();
            self.max = v.clone();
        } else {
            if v.sql_cmp(&self.min) == Some(Ordering::Less) {
                self.min = v.clone();
            }
            if v.sql_cmp(&self.max) == Some(Ordering::Greater) {
                self.max = v.clone();
            }
        }
        self.bounded += 1;
    }

    /// Can no row of this chunk satisfy `column <op> v`? Decisions reuse
    /// `sql_cmp` — the exact comparison the row filter runs — so pruning
    /// is sound by construction: `true` here means every per-row compare
    /// would come out `false`.
    pub fn excludes(&self, op: Op, v: &Value) -> bool {
        if self.bounded == 0 {
            // only NULLs / NaNs in this column: no comparison matches
            return true;
        }
        let (vs_min, vs_max) = match (v.sql_cmp(&self.min), v.sql_cmp(&self.max)) {
            (Some(a), Some(b)) => (a, b),
            // v does not order against the column's values (e.g. a string
            // against numerics): every row compare yields None
            _ => return true,
        };
        match op {
            Op::Eq => vs_min == Ordering::Less || vs_max == Ordering::Greater,
            // min == v == max: every bounded value equals v, nothing differs
            Op::Ne => vs_min == Ordering::Equal && vs_max == Ordering::Equal,
            // a row < v exists only when min < v
            Op::Lt => vs_min != Ordering::Greater,
            Op::Le => vs_min == Ordering::Less,
            // a row > v exists only when max > v
            Op::Gt => vs_max != Ordering::Less,
            Op::Ge => vs_max == Ordering::Greater,
            _ => false,
        }
    }
}

/// One sealed, immutable span of [`CHUNK_SLOTS`] slots: shared row handles
/// in slot order plus per-column zone maps. Chunks are shared by `Arc`
/// between the store's seal cache and every snapshot taken while they stay
/// clean.
pub struct Chunk {
    rows: Vec<Option<Arc<Row>>>,
    /// Live rows in the span.
    pub live: usize,
    /// One entry per schema column; `None` for columns zone maps do not
    /// track (non-numeric types).
    zones: Vec<Option<Zone>>,
}

impl Chunk {
    /// Live rows in slot order.
    pub fn rows(&self) -> impl Iterator<Item = &Row> {
        self.rows.iter().filter_map(|r| r.as_deref())
    }

    /// Zone map of schema column `col`, when tracked.
    pub fn zone(&self, col: usize) -> Option<&Zone> {
        self.zones.get(col).and_then(|z| z.as_ref())
    }

    /// Conservative pre-filter: `false` means **no** row in this chunk can
    /// satisfy the conjunction, so the scan may skip it entirely. `true`
    /// promises nothing — callers still evaluate the predicate per row.
    pub fn may_match(&self, preds: &[Conjunct], params: &[Value]) -> bool {
        if self.live == 0 {
            return false;
        }
        for c in preds {
            let v = c.rhs.get(params);
            if v.is_null() {
                // a NULL comparison matches no row at all
                return false;
            }
            if let Some(Some(z)) = self.zones.get(c.col) {
                if z.excludes(c.op, v) {
                    return false;
                }
            }
        }
        true
    }
}

/// An immutable, shareable snapshot of one partition: its sealed chunks at
/// a single version. Cloning is one `Arc` bump; iteration yields live rows
/// in slot order, exactly like the slab itself.
#[derive(Clone)]
pub struct ChunkSnapshot(Arc<SnapInner>);

struct SnapInner {
    chunks: Vec<Arc<Chunk>>,
    live: usize,
    version: u64,
}

impl ChunkSnapshot {
    /// The sealed chunks, in slab order.
    pub fn chunks(&self) -> &[Arc<Chunk>] {
        &self.0.chunks
    }

    /// Live rows across all chunks.
    pub fn len(&self) -> usize {
        self.0.live
    }

    pub fn is_empty(&self) -> bool {
        self.0.live == 0
    }

    /// Partition version (== LSN) the snapshot was taken at.
    pub fn version(&self) -> u64 {
        self.0.version
    }

    /// Live rows in slot order.
    pub fn iter_rows(&self) -> impl Iterator<Item = &Row> {
        self.0.chunks.iter().flat_map(|c| c.rows())
    }

    /// Do two snapshots share the same assembled state? (Repeat snapshots
    /// between mutations return the identical object.)
    pub fn ptr_eq(a: &ChunkSnapshot, b: &ChunkSnapshot) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

/// In-memory storage for one partition of one table.
pub struct PartitionStore {
    def: Arc<TableDef>,
    /// Slab of shared row handles: `None` = free slot (reusable). The
    /// `Arc` is what makes snapshots, WAL records, and the mirrored backup
    /// apply alias one row materialization instead of deep-copying it.
    rows: Vec<Option<Arc<Row>>>,
    /// Free slots, allocated smallest-first (canonical — see module docs).
    free: BTreeSet<Slot>,
    live: usize,
    /// Primary-key hash index (unique within the partition; the cluster
    /// routes equal keys to one partition so per-partition uniqueness is
    /// table-wide for partition-aligned keys, and the cluster additionally
    /// checks across partitions on insert when PK != partition key).
    pk: FxHashMap<i64, Slot>,
    /// Secondary indexes: column schema idx -> (value hash -> slots).
    secondary: Vec<(usize, FxHashMap<u64, Vec<Slot>>)>,
    /// Monotone version, bumped on every mutation. This doubles as the
    /// partition's **log sequence number**: redo records store the version
    /// right after their op applied, and replicas advance in lockstep
    /// (aborted transactions restore the pre-transaction version, so the
    /// sequence stays dense).
    pub version: u64,
    /// Epoch fence: the cluster epoch this replica last (re)joined under.
    /// Redo records from an older epoch are rejected by
    /// [`PartitionStore::apply_redo`] — a stale rejoiner cannot clobber
    /// writes committed after a promotion it never saw.
    pub epoch: u64,
    /// Per-slot OCC write stamps: `stamps[slot]` holds the value
    /// `stamp_clock` had when the slot was last mutated (insert, update,
    /// or delete). The optimistic point-DML path reads a stamp without
    /// write latches and revalidates it in its commit critical section —
    /// equality means the slot was untouched in between. The clock is
    /// **node-local validation state**, deliberately kept out of
    /// snapshots, checkpoints, and `fingerprint()`: it never rewinds (not
    /// even on abort — aborts restore `version`, and a rewinding stamp
    /// would reopen the ABA window the stamp exists to close), and
    /// [`PartitionStore::wipe`] clears the slots but keeps the clock so a
    /// re-seeded replica can never re-mint a previously observed stamp.
    stamps: Vec<u64>,
    stamp_clock: u64,
    approx_bytes: usize,
    /// Seal cache: one slot per chunk span; `Some` holds the immutable
    /// sealed chunk shared with snapshots, `None` is the dirty bit set by
    /// any mutation inside the span. Interior mutability because sealing
    /// happens under the partition *read* latch (`snapshot(&self)`), which
    /// excludes writers but not fellow readers.
    sealed: Mutex<Vec<Option<Arc<Chunk>>>>,
    /// Assembled snapshot cache, keyed by the version it was taken at:
    /// repeat readers between mutations get the same handle back for the
    /// cost of an `Arc` clone (see [`PartitionStore::snapshot`]).
    snap: Mutex<Option<(u64, ChunkSnapshot)>>,
}

impl PartitionStore {
    pub fn new(def: Arc<TableDef>) -> PartitionStore {
        let secondary = def
            .indexes
            .iter()
            .filter_map(|c| def.schema.index_of(c))
            .map(|ci| (ci, FxHashMap::default()))
            .collect();
        PartitionStore {
            def,
            rows: Vec::new(),
            free: BTreeSet::new(),
            live: 0,
            pk: FxHashMap::default(),
            secondary,
            version: 0,
            epoch: 0,
            stamps: Vec::new(),
            stamp_clock: 0,
            approx_bytes: 0,
            sealed: Mutex::new(Vec::new()),
            snap: Mutex::new(None),
        }
    }

    pub fn def(&self) -> &Arc<TableDef> {
        &self.def
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Slab capacity (live rows + free holes). Checkpoints record it so a
    /// reconstructed replica reproduces the hole set exactly — including
    /// trailing holes, which influence future canonical slot choices.
    pub fn slab_cap(&self) -> usize {
        self.rows.len()
    }

    /// Approximate resident bytes of the rows this store **owns** (indexes
    /// excluded). Each row is counted exactly once no matter how many
    /// `Arc` aliases of it exist — cached snapshot chunks, in-flight WAL
    /// records, and scans hold handles, not copies, so they add nothing
    /// here. (The mirrored backup replica counts its own handles: the two
    /// stores report independently even when they share row allocations.)
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    /// Keep the seal cache sized to the slab (one entry per chunk span).
    fn sync_sealed_len(&mut self) {
        let n = chunk_count(self.rows.len());
        let s = self.sealed.get_mut().unwrap();
        if s.len() < n {
            s.resize(n, None);
        }
    }

    /// Mark the chunk containing `slot` dirty (drops its sealed form; the
    /// next snapshot re-seals it from the slab).
    fn mark_dirty(&mut self, slot: Slot) {
        let s = self.sealed.get_mut().unwrap();
        let ci = slot / CHUNK_SLOTS;
        if ci < s.len() {
            s[ci] = None;
        }
    }

    fn pk_of(&self, row: &Row) -> Option<i64> {
        let i = self.def.pk_idx()?;
        row.values[i].as_i64()
    }

    /// Validate a shared row against the schema. Rows that need the
    /// Int→Float widening are re-materialized; already-canonical rows
    /// (everything coming out of another store, the WAL, or a checkpoint)
    /// keep their allocation and just bump the refcount.
    fn coerce_shared(&self, row: Arc<Row>) -> Result<Arc<Row>> {
        self.def.schema.check_row(&row)?;
        let needs_widening = row
            .values
            .iter()
            .zip(&self.def.schema.columns)
            .any(|(v, c)| c.ty == ColumnType::Float && matches!(v, Value::Int(_)));
        if needs_widening {
            Ok(Arc::new(self.def.schema.coerce_row(row.as_ref().clone())?))
        } else {
            Ok(row)
        }
    }

    fn index_insert(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            map.entry(row.values[*ci].hash_key()).or_default().push(slot);
        }
    }

    fn index_remove(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            let key = row.values[*ci].hash_key();
            if let Some(v) = map.get_mut(&key) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&key);
                }
            }
        }
    }

    /// Index maintenance for an in-place row replacement: only buckets whose
    /// key actually changed are touched. On the task-claim hot loop the
    /// typical update rewrites `status` plus a couple of unindexed columns,
    /// so every other secondary index is left alone. Shared by
    /// [`PartitionStore::update`] and [`PartitionStore::update_in_place`].
    fn index_update(&mut self, slot: Slot, old: &Row, new: &Row) {
        for (ci, map) in &mut self.secondary {
            let ok = old.values[*ci].hash_key();
            let nk = new.values[*ci].hash_key();
            if ok == nk {
                continue;
            }
            if let Some(v) = map.get_mut(&ok) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&ok);
                }
            }
            map.entry(nk).or_default().push(slot);
        }
    }

    /// Advance the monotone stamp clock and stamp `slot` with it. Called
    /// by every slot mutation (the shared insert tail, update, delete) so
    /// an OCC validator that re-reads an equal stamp knows the slot saw no
    /// intervening write.
    fn bump_stamp(&mut self, slot: Slot) {
        if self.stamps.len() < self.rows.len() {
            self.stamps.resize(self.rows.len(), 0);
        }
        self.stamp_clock += 1;
        self.stamps[slot] = self.stamp_clock;
    }

    /// The OCC write stamp of `slot` (0 = never written since the last
    /// wipe). See the `stamps` field docs for the validation protocol.
    pub fn slot_stamp(&self, slot: Slot) -> u64 {
        self.stamps.get(slot).copied().unwrap_or(0)
    }

    /// Place a validated row at a specific slot. Shared tail of the insert
    /// paths; the slot must already be carved out of the free set / slab.
    fn place(&mut self, slot: Slot, row: Arc<Row>) {
        self.approx_bytes += row.approx_bytes();
        if let Some(k) = self.pk_of(&row) {
            self.pk.insert(k, slot);
        }
        self.index_insert(slot, &row);
        self.rows[slot] = Some(row);
        self.live += 1;
        self.version += 1;
        self.mark_dirty(slot);
        self.bump_stamp(slot);
    }

    /// Insert a validated row; returns its slot (always the smallest free
    /// one — canonical allocation, see module docs).
    pub fn insert(&mut self, row: Row) -> Result<Slot> {
        let row = self.def.schema.coerce_row(row)?;
        self.insert_valid(Arc::new(row))
    }

    /// [`PartitionStore::insert`] over a shared handle: the row keeps its
    /// allocation (backup apply, redo replay — one materialization per
    /// committed row across every replica and the WAL).
    pub fn insert_arc(&mut self, row: Arc<Row>) -> Result<Slot> {
        let row = self.coerce_shared(row)?;
        self.insert_valid(row)
    }

    fn insert_valid(&mut self, row: Arc<Row>) -> Result<Slot> {
        if let Some(k) = self.pk_of(&row) {
            if self.pk.contains_key(&k) {
                return Err(Error::Constraint(format!(
                    "duplicate primary key {k} in '{}'",
                    self.def.name
                )));
            }
        }
        let slot = match self.free.pop_first() {
            Some(s) => s,
            None => {
                self.rows.push(None);
                self.sync_sealed_len();
                self.rows.len() - 1
            }
        };
        self.place(slot, row);
        Ok(slot)
    }

    /// Insert a validated row at a **specific** slot, growing the slab if
    /// needed (intermediate slots become free holes). This is the
    /// slot-addressed form used by replica apply, redo replay, and
    /// transaction rollback — every path where the slot was chosen
    /// elsewhere and divergence must surface as an error, not a silent
    /// relocation.
    pub fn insert_at(&mut self, slot: Slot, row: Row) -> Result<()> {
        let row = self.def.schema.coerce_row(row)?;
        self.insert_at_valid(slot, Arc::new(row))
    }

    /// [`PartitionStore::insert_at`] over a shared handle (replica apply /
    /// replay share the primary's materialization).
    pub fn insert_at_arc(&mut self, slot: Slot, row: Arc<Row>) -> Result<()> {
        let row = self.coerce_shared(row)?;
        self.insert_at_valid(slot, row)
    }

    fn insert_at_valid(&mut self, slot: Slot, row: Arc<Row>) -> Result<()> {
        if let Some(k) = self.pk_of(&row) {
            if self.pk.contains_key(&k) {
                return Err(Error::Constraint(format!(
                    "duplicate primary key {k} in '{}'",
                    self.def.name
                )));
            }
        }
        while self.rows.len() <= slot {
            self.free.insert(self.rows.len());
            self.rows.push(None);
        }
        self.sync_sealed_len();
        if self.rows[slot].is_some() {
            return Err(Error::Constraint(format!(
                "slot {slot} already occupied in '{}'",
                self.def.name
            )));
        }
        self.free.remove(&slot);
        self.place(slot, row);
        Ok(())
    }

    /// Read a row by slot.
    pub fn get(&self, slot: Slot) -> Option<&Row> {
        self.rows.get(slot).and_then(|r| r.as_deref())
    }

    /// Shared handle to the row at `slot` (an `Arc` bump, not a copy).
    pub fn get_arc(&self, slot: Slot) -> Option<Arc<Row>> {
        self.rows.get(slot).and_then(|r| r.clone())
    }

    /// Slot for a primary-key value.
    pub fn slot_by_pk(&self, key: i64) -> Option<Slot> {
        self.pk.get(&key).copied()
    }

    /// Candidate slots where `column == value`, using a secondary index if
    /// one exists. Returns `None` when the column is not indexed (caller
    /// must scan); the borrowed slice may contain hash-collision false
    /// positives, so callers still re-check the predicate. Borrowing (rather
    /// than cloning the bucket) matters on the claim loop, where the `READY`
    /// bucket can span most of a partition.
    pub fn slots_by_index(&self, col_idx: usize, value: &Value) -> Option<&[Slot]> {
        let (_, map) = self.secondary.iter().find(|(ci, _)| *ci == col_idx)?;
        Some(match map.get(&value.hash_key()) {
            Some(v) => v.as_slice(),
            None => &[],
        })
    }

    /// Overwrite the row at `slot` with a validated new row.
    pub fn update(&mut self, slot: Slot, new_row: Row) -> Result<()> {
        self.update_in_place(slot, new_row).map(|_| ())
    }

    /// Overwrite the row at `slot` and hand the displaced old row's handle
    /// back to the caller (the caller typically keeps it as undo state and
    /// for change detection — an `Arc` bump, never a clone). Secondary
    /// indexes are only rewritten for columns whose value actually changed
    /// — the fast DML path's point updates flip `status` and leave the
    /// rest alone.
    pub fn update_in_place(&mut self, slot: Slot, new_row: Row) -> Result<Arc<Row>> {
        let new_row = self.def.schema.coerce_row(new_row)?;
        self.update_valid(slot, Arc::new(new_row))
    }

    /// [`PartitionStore::update_in_place`] over a shared handle: the
    /// primary's materialization is applied to the backup and logged
    /// without re-cloning the row.
    pub fn update_arc(&mut self, slot: Slot, new_row: Arc<Row>) -> Result<Arc<Row>> {
        let new_row = self.coerce_shared(new_row)?;
        self.update_valid(slot, new_row)
    }

    fn update_valid(&mut self, slot: Slot, new_row: Arc<Row>) -> Result<Arc<Row>> {
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("update of dead slot {slot}")))?;
        // Primary key immutability keeps the index trivially consistent;
        // the workflow engine never rewrites task ids.
        if let (Some(a), Some(b)) = (self.pk_of(&old), self.pk_of(&new_row)) {
            if a != b {
                self.rows[slot] = Some(old);
                return Err(Error::Constraint(format!(
                    "primary key is immutable ({a} -> {b})"
                )));
            }
        }
        self.index_update(slot, &old, &new_row);
        self.approx_bytes = self.approx_bytes - old.approx_bytes() + new_row.approx_bytes();
        self.rows[slot] = Some(new_row);
        self.version += 1;
        self.mark_dirty(slot);
        self.bump_stamp(slot);
        Ok(old)
    }

    /// Delete the row at `slot`; returns the removed row's handle.
    pub fn delete(&mut self, slot: Slot) -> Result<Arc<Row>> {
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("delete of dead slot {slot}")))?;
        if let Some(k) = self.pk_of(&old) {
            self.pk.remove(&k);
        }
        self.index_remove(slot, &old);
        self.approx_bytes -= old.approx_bytes();
        self.free.insert(slot);
        self.live -= 1;
        self.version += 1;
        self.mark_dirty(slot);
        self.bump_stamp(slot);
        Ok(old)
    }

    /// Apply one redo record (replica catch-up / WAL replay), idempotently:
    ///
    /// - a record at or below the current version was already applied —
    ///   skipped, `Ok(false)`;
    /// - the next record in sequence (`lsn == version + 1`) applies and
    ///   advances the version to exactly `lsn`, `Ok(true)`;
    /// - a gap (`lsn > version + 1`) is unrecoverable from this stream —
    ///   the caller falls back to a snapshot re-seed;
    /// - a record from an **older epoch** than this replica's fence is
    ///   rejected outright: a stale rejoiner replaying its pre-crash log
    ///   must not clobber rows committed after the promotion it missed.
    pub fn apply_redo(&mut self, rec: &WalRecord) -> Result<bool> {
        if rec.lsn <= self.version {
            // already applied (idempotent skip) — checked before the fence
            // so a late duplicate from an old epoch cannot halt replay
            return Ok(false);
        }
        if rec.epoch < self.epoch {
            return Err(Error::TxnAborted(format!(
                "fenced: redo record epoch {} below replica epoch {} on '{}'",
                rec.epoch, self.epoch, self.def.name
            )));
        }
        if rec.lsn > self.version + 1 {
            return Err(Error::TxnAborted(format!(
                "redo gap on '{}': have lsn {}, next record is {}",
                self.def.name, self.version, rec.lsn
            )));
        }
        match &rec.op {
            LogOp::Insert { slot, row, .. } => self.insert_at_arc(*slot, row.clone())?,
            LogOp::Update { slot, row, .. } => {
                self.update_arc(*slot, row.clone())?;
            }
            LogOp::Delete { slot, .. } => {
                self.delete(*slot)?;
            }
        }
        debug_assert_eq!(self.version, rec.lsn, "mutations bump the version by exactly one");
        Ok(true)
    }

    /// Iterate live `(slot, row)` pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (Slot, &Row)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_deref().map(|row| (i, row)))
    }

    /// Deep copy of all live rows (legacy checkpointing / bulk export —
    /// and the baseline the snapshot microbenchmark compares the chunked
    /// path against).
    pub fn snapshot_rows(&self) -> Vec<Row> {
        self.iter().map(|(_, r)| r.clone()).collect()
    }

    /// **Slot-preserving** snapshot of shared row handles: `(slab
    /// capacity, live rows with their slots)`. This is the replica-seeding
    /// format — reloading it via [`PartitionStore::load_slotted`]
    /// reproduces the slab layout (holes included) so slot-addressed redo
    /// keeps applying cleanly afterwards. Rows ship as `Arc` handles: a
    /// heal or rejoin re-seed aliases the primary's materializations
    /// instead of deep-copying every live row.
    pub fn snapshot_slotted(&self) -> (usize, Vec<(Slot, Arc<Row>)>) {
        (
            self.rows.len(),
            self.rows
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.clone().map(|row| (i, row)))
                .collect(),
        )
    }

    /// Versioned copy-on-write snapshot: clean chunks are `Arc`-bumped,
    /// dirty ones re-sealed from the slab (row-handle bumps + zone-map
    /// computation — never row deep-copies), so the cost under the
    /// partition read latch is O(changed chunks), not O(partition).
    ///
    /// The assembled snapshot is cached per version: repeat readers
    /// between mutations get the same handle back for the cost of a
    /// clone. Callers hold the partition's read latch only long enough to
    /// call this; query execution then proceeds against the immutable
    /// snapshot with **no partition lock held**, which is what keeps the
    /// steering analytics off the scheduler's 2PL critical path.
    pub fn snapshot(&self) -> ChunkSnapshot {
        {
            let g = self.snap.lock().unwrap();
            if let Some((v, s)) = g.as_ref() {
                if *v == self.version {
                    return s.clone();
                }
            }
        }
        let nchunks = chunk_count(self.rows.len());
        let chunks: Vec<Arc<Chunk>> = {
            let mut sealed = self.sealed.lock().unwrap();
            if sealed.len() < nchunks {
                // defensive: mutation paths keep this in sync
                sealed.resize(nchunks, None);
            }
            let mut chunks = Vec::with_capacity(nchunks);
            for ci in 0..nchunks {
                let c = if let Some(c) = sealed[ci].as_ref() {
                    c.clone()
                } else {
                    let c = Arc::new(self.seal_chunk(ci));
                    sealed[ci] = Some(c.clone());
                    c
                };
                chunks.push(c);
            }
            chunks
        };
        let snap = ChunkSnapshot(Arc::new(SnapInner {
            chunks,
            live: self.live,
            version: self.version,
        }));
        *self.snap.lock().unwrap() = Some((self.version, snap.clone()));
        snap
    }

    /// Seal chunk `ci` from the slab: bump the span's row handles and fold
    /// the zone maps.
    fn seal_chunk(&self, ci: usize) -> Chunk {
        let base = ci * CHUNK_SLOTS;
        let end = ((ci + 1) * CHUNK_SLOTS).min(self.rows.len());
        let rows: Vec<Option<Arc<Row>>> = self.rows[base..end].to_vec();
        let mut zones: Vec<Option<Zone>> = self
            .def
            .schema
            .columns
            .iter()
            .map(|c| match c.ty {
                ColumnType::Int | ColumnType::Float => Some(Zone::default()),
                _ => None,
            })
            .collect();
        let mut live = 0;
        for r in rows.iter().flatten() {
            live += 1;
            for (v, z) in r.values.iter().zip(zones.iter_mut()) {
                if let Some(z) = z {
                    z.fold(v);
                }
            }
        }
        Chunk { rows, live, zones }
    }

    /// Rebuild the store from a row list (compacting; legacy recovery and
    /// test seeding — replica seeding uses [`PartitionStore::load_slotted`]).
    ///
    /// Drops any cached snapshot state: callers may assign `version`
    /// non-monotonically after a reload, so a stale cache entry could
    /// otherwise collide with a future version of different content.
    pub fn load_rows(&mut self, rows: Vec<Row>) -> Result<()> {
        self.wipe();
        for r in rows {
            self.insert(r)?;
        }
        Ok(())
    }

    /// Rebuild the store from a slot-preserving snapshot (replica seeding,
    /// checkpoint load): the slab is sized to `cap` and every hole the
    /// source had — including trailing ones — is reproduced, so canonical
    /// slot allocation continues identically on both sides. Rows are
    /// shared handles (the re-seed aliases the source's allocations). The
    /// caller assigns `version` (and `epoch`) afterwards.
    pub fn load_slotted(&mut self, cap: usize, rows: Vec<(Slot, Arc<Row>)>) -> Result<()> {
        self.wipe();
        for s in 0..cap {
            self.free.insert(s);
            self.rows.push(None);
        }
        self.sync_sealed_len();
        for (slot, row) in rows {
            if slot >= cap {
                return Err(Error::Constraint(format!(
                    "slotted load: slot {slot} outside slab capacity {cap}"
                )));
            }
            self.insert_at_arc(slot, row)?;
        }
        Ok(())
    }

    /// Reset to empty (shared by the bulk loaders).
    fn wipe(&mut self) {
        *self.snap.get_mut().unwrap() = None;
        self.sealed.get_mut().unwrap().clear();
        self.rows.clear();
        self.free.clear();
        self.pk.clear();
        for (_, m) in &mut self.secondary {
            m.clear();
        }
        self.live = 0;
        self.approx_bytes = 0;
        // Stamps are cleared with the slab, but the clock survives: a
        // re-seeded replica re-stamps every row with strictly fresher
        // values, so no stamp an OCC reader observed pre-wipe can recur.
        self.stamps.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cexpr::CVal;
    use crate::storage::value::{ColumnType, Schema};

    fn store() -> PartitionStore {
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
            ("dur", ColumnType::Float),
        ]);
        let def = TableDef::new("wq", schema)
            .with_primary_key("taskid")
            .unwrap()
            .with_index("status")
            .unwrap();
        PartitionStore::new(Arc::new(def))
    }

    fn row(id: i64, w: i64, st: &str) -> Row {
        Row::new(vec![Value::Int(id), Value::Int(w), Value::str(st), Value::Float(1.0)])
    }

    #[test]
    fn insert_get_update_delete_cycle() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));

        p.update(s1, row(2, 0, "RUNNING")).unwrap();
        assert_eq!(p.get(s1).unwrap().values[2], Value::str("RUNNING"));

        let old = p.delete(s0).unwrap();
        assert_eq!(old.values[0], Value::Int(1));
        assert_eq!(p.len(), 1);
        assert!(p.get(s0).is_none());

        // slot reuse
        let s2 = p.insert(row(3, 1, "READY")).unwrap();
        assert_eq!(s2, s0);
    }

    #[test]
    fn canonical_allocation_takes_smallest_free_slot() {
        let mut p = store();
        for i in 0..5 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        // free slots {1, 3} in delete order 3, then 1
        p.delete(3).unwrap();
        p.delete(1).unwrap();
        // allocation is by slot number, not LIFO delete order
        assert_eq!(p.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(p.insert(row(11, 0, "READY")).unwrap(), 3);
        assert_eq!(p.insert(row(12, 0, "READY")).unwrap(), 5);
    }

    #[test]
    fn insert_at_reconstructs_exact_layout() {
        let mut p = store();
        p.insert_at(2, row(1, 0, "READY")).unwrap();
        assert_eq!(p.slab_cap(), 3, "slab grew to cover the slot");
        assert_eq!(p.len(), 1);
        // slots 0 and 1 are holes; canonical allocation fills them first
        assert_eq!(p.insert(row(2, 0, "READY")).unwrap(), 0);
        assert_eq!(p.insert(row(3, 0, "READY")).unwrap(), 1);
        // occupied slot is a hard error
        assert!(p.insert_at(2, row(9, 0, "READY")).is_err());
        // duplicate PK caught before any slab mutation
        assert!(p.insert_at(7, row(1, 0, "READY")).is_err());
        assert_eq!(p.slab_cap(), 3);
    }

    #[test]
    fn pk_uniqueness_and_lookup() {
        let mut p = store();
        p.insert(row(7, 0, "READY")).unwrap();
        assert!(matches!(p.insert(row(7, 1, "READY")), Err(Error::Constraint(_))));
        let slot = p.slot_by_pk(7).unwrap();
        assert_eq!(p.get(slot).unwrap().values[1], Value::Int(0));
        assert!(p.slot_by_pk(99).is_none());
    }

    #[test]
    fn pk_is_immutable_via_update() {
        let mut p = store();
        let s = p.insert(row(1, 0, "READY")).unwrap();
        assert!(p.update(s, row(2, 0, "READY")).is_err());
    }

    #[test]
    fn secondary_index_tracks_mutations() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        p.insert(row(3, 0, "RUNNING")).unwrap();
        let status_ci = 2;
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready.len(), 2);
        assert!(ready.contains(&s0) && ready.contains(&s1));

        p.update(s0, row(1, 0, "FINISHED")).unwrap();
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready, vec![s1]);
        let fin = p.slots_by_index(status_ci, &Value::str("FINISHED")).unwrap();
        assert_eq!(fin, vec![s0]);

        p.delete(s1).unwrap();
        assert!(p.slots_by_index(status_ci, &Value::str("READY")).unwrap().is_empty());
        // unindexed column -> None
        assert!(p.slots_by_index(0, &Value::Int(1)).is_none());
    }

    #[test]
    fn snapshot_and_reload() {
        let mut p = store();
        for i in 0..10 {
            p.insert(row(i, i % 3, "READY")).unwrap();
        }
        p.delete(p.slot_by_pk(4).unwrap()).unwrap();
        let snap = p.snapshot_rows();
        assert_eq!(snap.len(), 9);

        let mut q = store();
        q.load_rows(snap).unwrap();
        assert_eq!(q.len(), 9);
        assert!(q.slot_by_pk(4).is_none());
        assert!(q.slot_by_pk(5).is_some());
        // indexes rebuilt
        assert_eq!(q.slots_by_index(2, &Value::str("READY")).unwrap().len(), 9);
    }

    #[test]
    fn slotted_snapshot_reproduces_holes_and_allocation() {
        let mut p = store();
        for i in 0..6 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        p.delete(1).unwrap();
        p.delete(4).unwrap();
        p.delete(5).unwrap(); // trailing hole
        let (cap, rows) = p.snapshot_slotted();
        assert_eq!(cap, 6);
        assert_eq!(rows.len(), 3);

        let mut q = store();
        q.load_slotted(cap, rows).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.slab_cap(), 6);
        // both replicas now make identical canonical choices
        assert_eq!(p.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(q.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(p.insert(row(11, 0, "READY")).unwrap(), 4);
        assert_eq!(q.insert(row(11, 0, "READY")).unwrap(), 4);
        // out-of-cap slot rejected
        let mut r = store();
        assert!(r.load_slotted(2, vec![(5, Arc::new(row(1, 0, "X")))]).is_err());
    }

    #[test]
    fn slotted_snapshot_shares_row_allocations() {
        let mut p = store();
        for i in 0..4 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        let (cap, rows) = p.snapshot_slotted();
        let mut q = store();
        q.load_slotted(cap, rows).unwrap();
        // the re-seed aliases the source rows, it does not copy them
        for (slot, _) in p.iter().collect::<Vec<_>>() {
            let a = p.get_arc(slot).unwrap();
            let b = q.get_arc(slot).unwrap();
            assert!(Arc::ptr_eq(&a, &b), "slot {slot} was deep-copied");
        }
    }

    #[test]
    fn apply_redo_is_idempotent_and_gap_checked() {
        let mut primary = store();
        let mut replica = store();
        let mut recs: Vec<WalRecord> = Vec::new();
        for i in 0..4 {
            let slot = primary.insert(row(i, 0, "READY")).unwrap();
            recs.push(WalRecord {
                lsn: primary.version,
                epoch: 0,
                op: LogOp::Insert {
                    table: "wq".into(),
                    pidx: 0,
                    slot,
                    row: primary.get_arc(slot).unwrap(),
                },
            });
        }
        let s1 = primary.slot_by_pk(1).unwrap();
        primary.delete(s1).unwrap();
        recs.push(WalRecord {
            lsn: primary.version,
            epoch: 0,
            op: LogOp::Delete { table: "wq".into(), pidx: 0, slot: s1 },
        });
        for rec in &recs {
            assert!(replica.apply_redo(rec).unwrap());
        }
        assert_eq!(replica.version, primary.version);
        assert_eq!(replica.len(), primary.len());
        // replaying the same records is a no-op
        for rec in &recs {
            assert!(!replica.apply_redo(rec).unwrap());
        }
        assert_eq!(replica.version, primary.version);
        // a gap is an error, not silent corruption
        let gap = WalRecord {
            lsn: primary.version + 5,
            epoch: 0,
            op: LogOp::Delete { table: "wq".into(), pidx: 0, slot: 0 },
        };
        assert!(replica.apply_redo(&gap).is_err());
    }

    #[test]
    fn apply_redo_fences_stale_epochs() {
        let mut p = store();
        p.epoch = 2;
        let stale = WalRecord {
            lsn: 1,
            epoch: 1,
            op: LogOp::Insert {
                table: "wq".into(),
                pidx: 0,
                slot: 0,
                row: Arc::new(row(1, 0, "READY")),
            },
        };
        let e = p.apply_redo(&stale);
        assert!(e.is_err(), "stale-epoch record must be fenced");
        assert_eq!(p.len(), 0, "fenced record must not touch the store");
        let current = WalRecord { epoch: 2, ..stale };
        assert!(p.apply_redo(&current).unwrap());
    }

    #[test]
    fn update_in_place_returns_old_row_and_skips_unchanged_indexes() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        let status_ci = 2;
        // rewriting an unindexed column must leave the status bucket's
        // order untouched (no remove+reinsert churn)
        let before: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        let old = p
            .update_in_place(s0, Row::new(vec![
                Value::Int(1),
                Value::Int(7),
                Value::str("READY"),
                Value::Float(2.0),
            ]))
            .unwrap();
        assert_eq!(old.values[1], Value::Int(0), "old row handed back");
        assert_eq!(old.values[3], Value::Float(1.0));
        let after: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        assert_eq!(before, after, "unchanged index key must not be rewritten");
        // changing the indexed column still moves the slot between buckets
        p.update_in_place(s0, row(1, 7, "RUNNING")).unwrap();
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap(),
            &[s1][..]
        );
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("RUNNING")).unwrap(),
            &[s0][..]
        );
        // pk immutability enforced, store left intact on the error
        assert!(p.update_in_place(s0, row(9, 7, "RUNNING")).is_err());
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn byte_accounting_moves_with_rows() {
        let mut p = store();
        assert_eq!(p.approx_bytes(), 0);
        let s = p.insert(row(1, 0, "READY")).unwrap();
        let b1 = p.approx_bytes();
        assert!(b1 > 0);
        p.update(s, row(1, 0, "a-much-longer-status-string")).unwrap();
        assert!(p.approx_bytes() > b1);
        p.delete(s).unwrap();
        assert_eq!(p.approx_bytes(), 0);
    }

    /// Regression for the accounting rule under the chunked `Arc<Row>`
    /// representation: snapshots (and their sealed chunks) alias the
    /// store's rows, so taking any number of them must not change
    /// `approx_bytes`, and the number must always equal the sum over the
    /// *owned* live rows — aliases held by old snapshots don't count.
    #[test]
    fn byte_accounting_counts_unique_rows_not_snapshot_aliases() {
        let mut p = store();
        for i in 0..600 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        let owned: usize = p.iter().map(|(_, r)| r.approx_bytes()).sum();
        assert_eq!(p.approx_bytes(), owned);
        let before = p.approx_bytes();
        let s1 = p.snapshot();
        let s2 = p.snapshot();
        assert_eq!(
            p.approx_bytes(),
            before,
            "snapshots are aliases, not copies — accounting must not move"
        );
        // replace a row while snapshots still alias the old one: the store
        // accounts the new row only; the old row's memory is the
        // snapshots' to keep alive, not the store's to report
        p.update(0, row(0, 0, "a-significantly-longer-status-string")).unwrap();
        let owned_after: usize = p.iter().map(|(_, r)| r.approx_bytes()).sum();
        assert_eq!(p.approx_bytes(), owned_after);
        assert_eq!(s1.len(), 600);
        drop((s1, s2));
        let owned_final: usize = p.iter().map(|(_, r)| r.approx_bytes()).sum();
        assert_eq!(p.approx_bytes(), owned_final);
    }

    #[test]
    fn snapshot_is_cached_per_version() {
        let mut p = store();
        p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.snapshot();
        let s2 = p.snapshot();
        assert!(
            ChunkSnapshot::ptr_eq(&s1, &s2),
            "unchanged partition must reuse the snapshot"
        );
        assert_eq!(s1.len(), 1);
        p.insert(row(2, 0, "READY")).unwrap();
        let s3 = p.snapshot();
        assert!(!ChunkSnapshot::ptr_eq(&s1, &s3), "mutation must invalidate the cache");
        assert_eq!(s3.len(), 2);
        assert_eq!(s1.len(), 1, "an already-taken snapshot stays immutable");
    }

    /// The tentpole property: a point write dirties exactly one chunk, and
    /// the next snapshot re-seals only that chunk — every clean chunk is
    /// the *same* `Arc` as in the previous snapshot.
    #[test]
    fn snapshot_reseals_only_dirty_chunks() {
        let mut p = store();
        let n = CHUNK_SLOTS * 4 + 17; // 5 chunks, ragged tail
        for i in 0..n as i64 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        let s1 = p.snapshot();
        assert_eq!(s1.chunks().len(), 5);
        assert_eq!(s1.len(), n);

        // dirty exactly chunk 2
        let slot = CHUNK_SLOTS * 2 + 3;
        p.update(slot, row(slot as i64, 0, "RUNNING")).unwrap();
        let s2 = p.snapshot();
        assert!(!ChunkSnapshot::ptr_eq(&s1, &s2));
        for ci in 0..5 {
            let shared = Arc::ptr_eq(&s1.chunks()[ci], &s2.chunks()[ci]);
            if ci == 2 {
                assert!(!shared, "dirty chunk must be re-sealed");
            } else {
                assert!(shared, "clean chunk {ci} must be an Arc bump, not a rebuild");
            }
        }
        // row identity: even the re-sealed chunk shares the untouched rows
        let s1_rows: Vec<&Row> = s1.iter_rows().collect();
        let s2_rows: Vec<&Row> = s2.iter_rows().collect();
        assert_eq!(s1_rows.len(), s2_rows.len());
        assert_eq!(s1_rows[0], s2_rows[0]);
        assert_eq!(s1_rows[slot].values[2], Value::str("READY"), "old snapshot frozen");
        assert_eq!(s2_rows[slot].values[2], Value::str("RUNNING"));
    }

    #[test]
    fn snapshot_rows_in_slot_order_across_chunk_boundaries() {
        let mut p = store();
        let n = CHUNK_SLOTS + 10;
        for i in 0..n as i64 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        // holes on both sides of the chunk boundary
        p.delete(CHUNK_SLOTS - 1).unwrap();
        p.delete(CHUNK_SLOTS).unwrap();
        let s = p.snapshot();
        let ids: Vec<i64> = s
            .iter_rows()
            .map(|r| r.values[0].as_i64().unwrap())
            .collect();
        let mut expect: Vec<i64> = (0..n as i64).collect();
        expect.retain(|&i| i != (CHUNK_SLOTS - 1) as i64 && i != CHUNK_SLOTS as i64);
        assert_eq!(ids, expect, "chunked iteration must preserve slot order");
        assert_eq!(s.len(), n - 2);
    }

    #[test]
    fn zone_maps_bound_numeric_columns_and_prune_soundly() {
        let mut p = store();
        for i in 0..(CHUNK_SLOTS as i64 * 2) {
            p.insert(row(i, i % 4, "READY")).unwrap();
        }
        let s = p.snapshot();
        assert_eq!(s.chunks().len(), 2);
        let c0 = &s.chunks()[0];
        let z = c0.zone(0).expect("taskid is numeric");
        assert_eq!(z.min, Value::Int(0));
        assert_eq!(z.max, Value::Int(CHUNK_SLOTS as i64 - 1));
        assert!(c0.zone(2).is_none(), "string column has no zone map");

        let pred = |op: Op, v: i64| {
            vec![Conjunct { col: 0, op, rhs: CVal::Lit(Value::Int(v)) }]
        };
        // chunk 0 holds taskid 0..256, chunk 1 holds 256..512
        assert!(c0.may_match(&pred(Op::Eq, 5), &[]));
        assert!(!c0.may_match(&pred(Op::Eq, 300), &[]));
        assert!(!c0.may_match(&pred(Op::Gt, 255), &[]));
        assert!(c0.may_match(&pred(Op::Gt, 254), &[]));
        assert!(!c0.may_match(&pred(Op::Lt, 0), &[]));
        assert!(c0.may_match(&pred(Op::Le, 0), &[]));
        assert!(!c0.may_match(&pred(Op::Ge, 256), &[]));
        let c1 = &s.chunks()[1];
        assert!(c1.may_match(&pred(Op::Eq, 300), &[]));
        assert!(!c1.may_match(&pred(Op::Lt, 256), &[]));
        // NULL rhs never matches anything
        assert!(!c0.may_match(
            &[Conjunct { col: 0, op: Op::Eq, rhs: CVal::Lit(Value::Null) }],
            &[]
        ));
        // a string rhs cannot order against numerics: prune
        assert!(!c0.may_match(
            &[Conjunct { col: 0, op: Op::Eq, rhs: CVal::Lit(Value::str("x")) }],
            &[]
        ));
        // conservative on untracked columns: a status conjunct never prunes
        assert!(c0.may_match(
            &[Conjunct { col: 2, op: Op::Eq, rhs: CVal::Lit(Value::str("NOPE")) }],
            &[]
        ));
    }

    #[test]
    fn zone_maps_handle_nulls_and_nan() {
        let mut p = store();
        // dur column: one NaN, one NULL, two ordinary values
        p.insert(Row::new(vec![
            Value::Int(1),
            Value::Int(0),
            Value::str("R"),
            Value::Float(f64::NAN),
        ]))
        .unwrap();
        p.insert(Row::new(vec![Value::Int(2), Value::Int(0), Value::str("R"), Value::Null]))
            .unwrap();
        p.insert(row(3, 0, "R")).unwrap(); // dur 1.0
        p.insert(Row::new(vec![
            Value::Int(4),
            Value::Int(0),
            Value::str("R"),
            Value::Float(5.0),
        ]))
        .unwrap();
        let s = p.snapshot();
        let z = s.chunks()[0].zone(3).unwrap();
        assert_eq!(z.nulls, 1);
        assert_eq!(z.bounded, 2, "NaN must not enter the bounds");
        assert_eq!(z.min, Value::Float(1.0));
        assert_eq!(z.max, Value::Float(5.0));
        // bounds stay usable despite the NaN row
        let c = &s.chunks()[0];
        assert!(!c.may_match(
            &[Conjunct { col: 3, op: Op::Gt, rhs: CVal::Lit(Value::Float(5.0)) }],
            &[]
        ));
        assert!(c.may_match(
            &[Conjunct { col: 3, op: Op::Ge, rhs: CVal::Lit(Value::Float(5.0)) }],
            &[]
        ));

        // an all-NULL/NaN column prunes every comparison
        let mut q = store();
        q.insert(Row::new(vec![Value::Int(1), Value::Int(0), Value::str("R"), Value::Null]))
            .unwrap();
        let qs = q.snapshot();
        assert!(!qs.chunks()[0].may_match(
            &[Conjunct { col: 3, op: Op::Ne, rhs: CVal::Lit(Value::Float(0.0)) }],
            &[]
        ));
    }

    #[test]
    fn version_bumps_on_every_mutation() {
        let mut p = store();
        let v0 = p.version;
        let s = p.insert(row(1, 0, "READY")).unwrap();
        p.update(s, row(1, 0, "RUNNING")).unwrap();
        p.delete(s).unwrap();
        assert_eq!(p.version, v0 + 3);
    }

    #[test]
    fn arc_native_ops_share_the_materialization() {
        let mut a = store();
        let mut b = store();
        let r = Arc::new(a.def().schema.coerce_row(row(1, 0, "READY")).unwrap());
        let slot = a.insert_arc(r.clone()).unwrap();
        b.insert_at_arc(slot, r.clone()).unwrap();
        assert!(Arc::ptr_eq(&a.get_arc(slot).unwrap(), &b.get_arc(slot).unwrap()));
        // widening still happens when needed (Int literal into FLOAT col)
        let raw = Arc::new(Row::new(vec![
            Value::Int(2),
            Value::Int(0),
            Value::str("R"),
            Value::Int(3),
        ]));
        let s2 = a.insert_arc(raw).unwrap();
        assert_eq!(a.get(s2).unwrap().values[3], Value::Float(3.0));
    }

    #[test]
    fn slot_stamps_advance_on_every_mutation_and_never_rewind() {
        let mut p = store();
        let s = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.slot_stamp(s);
        assert!(s1 > 0, "an inserted slot is stamped");
        p.update(s, row(1, 0, "RUNNING")).unwrap();
        let s2 = p.slot_stamp(s);
        assert!(s2 > s1, "update re-stamps the slot");
        // an unrelated slot's mutation leaves this stamp alone
        let other = p.insert(row(2, 0, "READY")).unwrap();
        assert_eq!(p.slot_stamp(s), s2);
        assert!(p.slot_stamp(other) > s2, "the clock is store-wide monotone");
        p.delete(s).unwrap();
        assert!(p.slot_stamp(s) > s2, "delete re-stamps the vacated slot");
        // an abort-style version rewind must NOT rewind stamps: restoring
        // `version` is how the LSN sequence stays dense, but reusing an
        // observed stamp value would reopen the OCC ABA window
        let v = p.version;
        let s3 = p.insert(row(3, 0, "READY")).unwrap();
        let stamp3 = p.slot_stamp(s3);
        p.delete(s3).unwrap();
        p.version = v; // what fast_restore_versions does on abort
        let s4 = p.insert(row(3, 0, "READY")).unwrap();
        assert_eq!(s3, s4, "canonical allocation reuses the slot");
        assert!(p.slot_stamp(s4) > stamp3, "stamp keeps rising through the rewind");
    }

    #[test]
    fn reseed_stamps_are_fresher_than_anything_observed_before() {
        let mut p = store();
        let s = p.insert(row(1, 0, "READY")).unwrap();
        p.update(s, row(1, 0, "RUNNING")).unwrap();
        let observed = p.slot_stamp(s);
        let (cap, rows) = p.snapshot_slotted();
        p.load_slotted(cap, rows).unwrap();
        assert!(
            p.slot_stamp(s) > observed,
            "wipe clears stamps but keeps the clock, so re-seeded rows re-stamp fresh"
        );
    }
}
