//! A single table partition: slab-allocated rows plus hash indexes.
//!
//! Partitions are the unit of locking, replication and placement. The store
//! itself is lock-free-agnostic — concurrency control wraps it at the data
//! node (`RwLock<PartitionStore>`), mirroring how NDB data nodes own
//! fragments.

use crate::storage::table_def::TableDef;
use crate::storage::value::{Row, Value};
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::sync::{Arc, Mutex};

/// Slot handle inside a partition (stable until the row is deleted).
pub type Slot = usize;

/// In-memory storage for one partition of one table.
pub struct PartitionStore {
    def: Arc<TableDef>,
    /// Slab: `None` = free slot (reusable).
    rows: Vec<Option<Row>>,
    free: Vec<Slot>,
    live: usize,
    /// Primary-key hash index (unique within the partition; the cluster
    /// routes equal keys to one partition so per-partition uniqueness is
    /// table-wide for partition-aligned keys, and the cluster additionally
    /// checks across partitions on insert when PK != partition key).
    pk: FxHashMap<i64, Slot>,
    /// Secondary indexes: column schema idx -> (value hash -> slots).
    secondary: Vec<(usize, FxHashMap<u64, Vec<Slot>>)>,
    /// Monotone version, bumped on every mutation (replication + checkpoint
    /// consistency checks).
    pub version: u64,
    approx_bytes: usize,
    /// Cached clone-on-read snapshot, keyed by the version it was taken at.
    /// Serving the scatter-gather read path: readers clone the `Arc` and
    /// release the partition latch immediately, so analytical scans never
    /// hold partition locks while they execute (see [`PartitionStore::snapshot`]).
    snap: Mutex<Option<(u64, Arc<Vec<Row>>)>>,
}

impl PartitionStore {
    pub fn new(def: Arc<TableDef>) -> PartitionStore {
        let secondary = def
            .indexes
            .iter()
            .filter_map(|c| def.schema.index_of(c))
            .map(|ci| (ci, FxHashMap::default()))
            .collect();
        PartitionStore {
            def,
            rows: Vec::new(),
            free: Vec::new(),
            live: 0,
            pk: FxHashMap::default(),
            secondary,
            version: 0,
            approx_bytes: 0,
            snap: Mutex::new(None),
        }
    }

    pub fn def(&self) -> &Arc<TableDef> {
        &self.def
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Approximate resident bytes (rows only, indexes excluded).
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    fn pk_of(&self, row: &Row) -> Option<i64> {
        let i = self.def.pk_idx()?;
        row.values[i].as_i64()
    }

    fn index_insert(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            map.entry(row.values[*ci].hash_key()).or_default().push(slot);
        }
    }

    fn index_remove(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            let key = row.values[*ci].hash_key();
            if let Some(v) = map.get_mut(&key) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&key);
                }
            }
        }
    }

    /// Index maintenance for an in-place row replacement: only buckets whose
    /// key actually changed are touched. On the task-claim hot loop the
    /// typical update rewrites `status` plus a couple of unindexed columns,
    /// so every other secondary index is left alone. Shared by
    /// [`PartitionStore::update`] and [`PartitionStore::update_in_place`].
    fn index_update(&mut self, slot: Slot, old: &Row, new: &Row) {
        for (ci, map) in &mut self.secondary {
            let ok = old.values[*ci].hash_key();
            let nk = new.values[*ci].hash_key();
            if ok == nk {
                continue;
            }
            if let Some(v) = map.get_mut(&ok) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&ok);
                }
            }
            map.entry(nk).or_default().push(slot);
        }
    }

    /// Insert a validated row; returns its slot.
    pub fn insert(&mut self, row: Row) -> Result<Slot> {
        let row = self.def.schema.coerce_row(row)?;
        if let Some(k) = self.pk_of(&row) {
            if self.pk.contains_key(&k) {
                return Err(Error::Constraint(format!(
                    "duplicate primary key {k} in '{}'",
                    self.def.name
                )));
            }
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.rows.push(None);
                self.rows.len() - 1
            }
        };
        self.approx_bytes += row.approx_bytes();
        if let Some(k) = self.pk_of(&row) {
            self.pk.insert(k, slot);
        }
        self.index_insert(slot, &row);
        self.rows[slot] = Some(row);
        self.live += 1;
        self.version += 1;
        Ok(slot)
    }

    /// Read a row by slot.
    pub fn get(&self, slot: Slot) -> Option<&Row> {
        self.rows.get(slot).and_then(|r| r.as_ref())
    }

    /// Slot for a primary-key value.
    pub fn slot_by_pk(&self, key: i64) -> Option<Slot> {
        self.pk.get(&key).copied()
    }

    /// Candidate slots where `column == value`, using a secondary index if
    /// one exists. Returns `None` when the column is not indexed (caller
    /// must scan); the borrowed slice may contain hash-collision false
    /// positives, so callers still re-check the predicate. Borrowing (rather
    /// than cloning the bucket) matters on the claim loop, where the `READY`
    /// bucket can span most of a partition.
    pub fn slots_by_index(&self, col_idx: usize, value: &Value) -> Option<&[Slot]> {
        let (_, map) = self.secondary.iter().find(|(ci, _)| *ci == col_idx)?;
        Some(match map.get(&value.hash_key()) {
            Some(v) => v.as_slice(),
            None => &[],
        })
    }

    /// Overwrite the row at `slot` with a validated new row.
    pub fn update(&mut self, slot: Slot, new_row: Row) -> Result<()> {
        self.update_in_place(slot, new_row).map(|_| ())
    }

    /// Overwrite the row at `slot` and hand the displaced old row back to
    /// the caller **without cloning it** (the caller typically keeps it as
    /// undo state and for change detection). Secondary indexes are only
    /// rewritten for columns whose value actually changed — the fast DML
    /// path's point updates flip `status` and leave the rest alone.
    pub fn update_in_place(&mut self, slot: Slot, new_row: Row) -> Result<Row> {
        let new_row = self.def.schema.coerce_row(new_row)?;
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("update of dead slot {slot}")))?;
        // Primary key immutability keeps the index trivially consistent;
        // the workflow engine never rewrites task ids.
        if let (Some(a), Some(b)) = (self.pk_of(&old), self.pk_of(&new_row)) {
            if a != b {
                self.rows[slot] = Some(old);
                return Err(Error::Constraint(format!(
                    "primary key is immutable ({a} -> {b})"
                )));
            }
        }
        self.index_update(slot, &old, &new_row);
        self.approx_bytes = self.approx_bytes - old.approx_bytes() + new_row.approx_bytes();
        self.rows[slot] = Some(new_row);
        self.version += 1;
        Ok(old)
    }

    /// Delete the row at `slot`; returns the removed row.
    pub fn delete(&mut self, slot: Slot) -> Result<Row> {
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("delete of dead slot {slot}")))?;
        if let Some(k) = self.pk_of(&old) {
            self.pk.remove(&k);
        }
        self.index_remove(slot, &old);
        self.approx_bytes -= old.approx_bytes();
        self.free.push(slot);
        self.live -= 1;
        self.version += 1;
        Ok(old)
    }

    /// Iterate live `(slot, row)` pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (Slot, &Row)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|row| (i, row)))
    }

    /// Deep copy of all live rows (checkpointing / replica seeding).
    pub fn snapshot_rows(&self) -> Vec<Row> {
        self.iter().map(|(_, r)| r.clone()).collect()
    }

    /// Versioned snapshot of the live rows in slot order, shared via `Arc`.
    ///
    /// The rows are materialized at most once per partition version: repeat
    /// readers between mutations get the same `Arc` back for the cost of a
    /// clone. Callers hold the partition's read latch only long enough to
    /// call this; query execution then proceeds against the immutable
    /// snapshot with **no partition lock held**, which is what keeps the
    /// steering analytics off the scheduler's 2PL critical path.
    pub fn snapshot(&self) -> Arc<Vec<Row>> {
        let mut g = self.snap.lock().unwrap();
        if let Some((v, rows)) = g.as_ref() {
            if *v == self.version {
                return rows.clone();
            }
        }
        let rows = Arc::new(self.snapshot_rows());
        *g = Some((self.version, rows.clone()));
        rows
    }

    /// Rebuild the store from a row list (recovery / replica seeding).
    ///
    /// Drops any cached snapshot: callers (e.g. `DbCluster::heal`) may
    /// assign `version` non-monotonically after a reload, so a stale cache
    /// entry could otherwise collide with a future version of different
    /// content.
    pub fn load_rows(&mut self, rows: Vec<Row>) -> Result<()> {
        *self.snap.lock().unwrap() = None;
        self.rows.clear();
        self.free.clear();
        self.pk.clear();
        for (_, m) in &mut self.secondary {
            m.clear();
        }
        self.live = 0;
        self.approx_bytes = 0;
        for r in rows {
            self.insert(r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::{ColumnType, Schema};

    fn store() -> PartitionStore {
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
            ("dur", ColumnType::Float),
        ]);
        let def = TableDef::new("wq", schema)
            .with_primary_key("taskid")
            .unwrap()
            .with_index("status")
            .unwrap();
        PartitionStore::new(Arc::new(def))
    }

    fn row(id: i64, w: i64, st: &str) -> Row {
        Row::new(vec![Value::Int(id), Value::Int(w), Value::str(st), Value::Float(1.0)])
    }

    #[test]
    fn insert_get_update_delete_cycle() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));

        p.update(s1, row(2, 0, "RUNNING")).unwrap();
        assert_eq!(p.get(s1).unwrap().values[2], Value::str("RUNNING"));

        let old = p.delete(s0).unwrap();
        assert_eq!(old.values[0], Value::Int(1));
        assert_eq!(p.len(), 1);
        assert!(p.get(s0).is_none());

        // slot reuse
        let s2 = p.insert(row(3, 1, "READY")).unwrap();
        assert_eq!(s2, s0);
    }

    #[test]
    fn pk_uniqueness_and_lookup() {
        let mut p = store();
        p.insert(row(7, 0, "READY")).unwrap();
        assert!(matches!(p.insert(row(7, 1, "READY")), Err(Error::Constraint(_))));
        let slot = p.slot_by_pk(7).unwrap();
        assert_eq!(p.get(slot).unwrap().values[1], Value::Int(0));
        assert!(p.slot_by_pk(99).is_none());
    }

    #[test]
    fn pk_is_immutable_via_update() {
        let mut p = store();
        let s = p.insert(row(1, 0, "READY")).unwrap();
        assert!(p.update(s, row(2, 0, "READY")).is_err());
    }

    #[test]
    fn secondary_index_tracks_mutations() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        p.insert(row(3, 0, "RUNNING")).unwrap();
        let status_ci = 2;
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready.len(), 2);
        assert!(ready.contains(&s0) && ready.contains(&s1));

        p.update(s0, row(1, 0, "FINISHED")).unwrap();
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready, vec![s1]);
        let fin = p.slots_by_index(status_ci, &Value::str("FINISHED")).unwrap();
        assert_eq!(fin, vec![s0]);

        p.delete(s1).unwrap();
        assert!(p.slots_by_index(status_ci, &Value::str("READY")).unwrap().is_empty());
        // unindexed column -> None
        assert!(p.slots_by_index(0, &Value::Int(1)).is_none());
    }

    #[test]
    fn snapshot_and_reload() {
        let mut p = store();
        for i in 0..10 {
            p.insert(row(i, i % 3, "READY")).unwrap();
        }
        p.delete(p.slot_by_pk(4).unwrap()).unwrap();
        let snap = p.snapshot_rows();
        assert_eq!(snap.len(), 9);

        let mut q = store();
        q.load_rows(snap).unwrap();
        assert_eq!(q.len(), 9);
        assert!(q.slot_by_pk(4).is_none());
        assert!(q.slot_by_pk(5).is_some());
        // indexes rebuilt
        assert_eq!(q.slots_by_index(2, &Value::str("READY")).unwrap().len(), 9);
    }

    #[test]
    fn update_in_place_returns_old_row_and_skips_unchanged_indexes() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        let status_ci = 2;
        // rewriting an unindexed column must leave the status bucket's
        // order untouched (no remove+reinsert churn)
        let before: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        let old = p
            .update_in_place(s0, Row::new(vec![
                Value::Int(1),
                Value::Int(7),
                Value::str("READY"),
                Value::Float(2.0),
            ]))
            .unwrap();
        assert_eq!(old.values[1], Value::Int(0), "old row handed back");
        assert_eq!(old.values[3], Value::Float(1.0));
        let after: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        assert_eq!(before, after, "unchanged index key must not be rewritten");
        // changing the indexed column still moves the slot between buckets
        p.update_in_place(s0, row(1, 7, "RUNNING")).unwrap();
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap(),
            &[s1][..]
        );
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("RUNNING")).unwrap(),
            &[s0][..]
        );
        // pk immutability enforced, store left intact on the error
        assert!(p.update_in_place(s0, row(9, 7, "RUNNING")).is_err());
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn byte_accounting_moves_with_rows() {
        let mut p = store();
        assert_eq!(p.approx_bytes(), 0);
        let s = p.insert(row(1, 0, "READY")).unwrap();
        let b1 = p.approx_bytes();
        assert!(b1 > 0);
        p.update(s, row(1, 0, "a-much-longer-status-string")).unwrap();
        assert!(p.approx_bytes() > b1);
        p.delete(s).unwrap();
        assert_eq!(p.approx_bytes(), 0);
    }

    #[test]
    fn snapshot_is_cached_per_version() {
        let mut p = store();
        p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.snapshot();
        let s2 = p.snapshot();
        assert!(Arc::ptr_eq(&s1, &s2), "unchanged partition must reuse the snapshot");
        assert_eq!(s1.len(), 1);
        p.insert(row(2, 0, "READY")).unwrap();
        let s3 = p.snapshot();
        assert!(!Arc::ptr_eq(&s1, &s3), "mutation must invalidate the cache");
        assert_eq!(s3.len(), 2);
        assert_eq!(s1.len(), 1, "an already-taken snapshot stays immutable");
    }

    #[test]
    fn version_bumps_on_every_mutation() {
        let mut p = store();
        let v0 = p.version;
        let s = p.insert(row(1, 0, "READY")).unwrap();
        p.update(s, row(1, 0, "RUNNING")).unwrap();
        p.delete(s).unwrap();
        assert_eq!(p.version, v0 + 3);
    }
}
