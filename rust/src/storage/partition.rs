//! A single table partition: slab-allocated rows plus hash indexes.
//!
//! Partitions are the unit of locking, replication, placement — and, since
//! the durability rework, of *logging*: every committed mutation carries
//! the partition's dense log sequence number (its `version` right after
//! the op applied), so a replica can be reconstructed from a checkpoint
//! plus a redo tail and then audited against the primary by LSN alone.
//!
//! Slot allocation is **canonical**: an insert always takes the smallest
//! free slot. That makes the slab layout a pure function of the committed
//! op history — two replicas that applied the same ops agree on every
//! future slot choice, which is what lets redo records address rows by
//! slot (and lets the chaos tests demand byte-equality between a rejoined
//! node and a never-killed twin).

use crate::storage::table_def::TableDef;
use crate::storage::value::{Row, Value};
use crate::storage::wal::{LogOp, WalRecord};
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex};

/// Slot handle inside a partition (stable until the row is deleted).
pub type Slot = usize;

/// In-memory storage for one partition of one table.
pub struct PartitionStore {
    def: Arc<TableDef>,
    /// Slab: `None` = free slot (reusable).
    rows: Vec<Option<Row>>,
    /// Free slots, allocated smallest-first (canonical — see module docs).
    free: BTreeSet<Slot>,
    live: usize,
    /// Primary-key hash index (unique within the partition; the cluster
    /// routes equal keys to one partition so per-partition uniqueness is
    /// table-wide for partition-aligned keys, and the cluster additionally
    /// checks across partitions on insert when PK != partition key).
    pk: FxHashMap<i64, Slot>,
    /// Secondary indexes: column schema idx -> (value hash -> slots).
    secondary: Vec<(usize, FxHashMap<u64, Vec<Slot>>)>,
    /// Monotone version, bumped on every mutation. This doubles as the
    /// partition's **log sequence number**: redo records store the version
    /// right after their op applied, and replicas advance in lockstep
    /// (aborted transactions restore the pre-transaction version, so the
    /// sequence stays dense).
    pub version: u64,
    /// Epoch fence: the cluster epoch this replica last (re)joined under.
    /// Redo records from an older epoch are rejected by
    /// [`PartitionStore::apply_redo`] — a stale rejoiner cannot clobber
    /// writes committed after a promotion it never saw.
    pub epoch: u64,
    approx_bytes: usize,
    /// Cached clone-on-read snapshot, keyed by the version it was taken at.
    /// Serving the scatter-gather read path: readers clone the `Arc` and
    /// release the partition latch immediately, so analytical scans never
    /// hold partition locks while they execute (see [`PartitionStore::snapshot`]).
    snap: Mutex<Option<(u64, Arc<Vec<Row>>)>>,
}

impl PartitionStore {
    pub fn new(def: Arc<TableDef>) -> PartitionStore {
        let secondary = def
            .indexes
            .iter()
            .filter_map(|c| def.schema.index_of(c))
            .map(|ci| (ci, FxHashMap::default()))
            .collect();
        PartitionStore {
            def,
            rows: Vec::new(),
            free: BTreeSet::new(),
            live: 0,
            pk: FxHashMap::default(),
            secondary,
            version: 0,
            epoch: 0,
            approx_bytes: 0,
            snap: Mutex::new(None),
        }
    }

    pub fn def(&self) -> &Arc<TableDef> {
        &self.def
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Slab capacity (live rows + free holes). Checkpoints record it so a
    /// reconstructed replica reproduces the hole set exactly — including
    /// trailing holes, which influence future canonical slot choices.
    pub fn slab_cap(&self) -> usize {
        self.rows.len()
    }

    /// Approximate resident bytes (rows only, indexes excluded).
    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    fn pk_of(&self, row: &Row) -> Option<i64> {
        let i = self.def.pk_idx()?;
        row.values[i].as_i64()
    }

    fn index_insert(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            map.entry(row.values[*ci].hash_key()).or_default().push(slot);
        }
    }

    fn index_remove(&mut self, slot: Slot, row: &Row) {
        for (ci, map) in &mut self.secondary {
            let key = row.values[*ci].hash_key();
            if let Some(v) = map.get_mut(&key) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&key);
                }
            }
        }
    }

    /// Index maintenance for an in-place row replacement: only buckets whose
    /// key actually changed are touched. On the task-claim hot loop the
    /// typical update rewrites `status` plus a couple of unindexed columns,
    /// so every other secondary index is left alone. Shared by
    /// [`PartitionStore::update`] and [`PartitionStore::update_in_place`].
    fn index_update(&mut self, slot: Slot, old: &Row, new: &Row) {
        for (ci, map) in &mut self.secondary {
            let ok = old.values[*ci].hash_key();
            let nk = new.values[*ci].hash_key();
            if ok == nk {
                continue;
            }
            if let Some(v) = map.get_mut(&ok) {
                if let Some(p) = v.iter().position(|s| *s == slot) {
                    v.swap_remove(p);
                }
                if v.is_empty() {
                    map.remove(&ok);
                }
            }
            map.entry(nk).or_default().push(slot);
        }
    }

    /// Place a validated row at a specific slot. Shared tail of
    /// [`PartitionStore::insert`] and [`PartitionStore::insert_at`]; the
    /// slot must already be carved out of the free set / slab.
    fn place(&mut self, slot: Slot, row: Row) {
        self.approx_bytes += row.approx_bytes();
        if let Some(k) = self.pk_of(&row) {
            self.pk.insert(k, slot);
        }
        self.index_insert(slot, &row);
        self.rows[slot] = Some(row);
        self.live += 1;
        self.version += 1;
    }

    /// Insert a validated row; returns its slot (always the smallest free
    /// one — canonical allocation, see module docs).
    pub fn insert(&mut self, row: Row) -> Result<Slot> {
        let row = self.def.schema.coerce_row(row)?;
        if let Some(k) = self.pk_of(&row) {
            if self.pk.contains_key(&k) {
                return Err(Error::Constraint(format!(
                    "duplicate primary key {k} in '{}'",
                    self.def.name
                )));
            }
        }
        let slot = match self.free.pop_first() {
            Some(s) => s,
            None => {
                self.rows.push(None);
                self.rows.len() - 1
            }
        };
        self.place(slot, row);
        Ok(slot)
    }

    /// Insert a validated row at a **specific** slot, growing the slab if
    /// needed (intermediate slots become free holes). This is the
    /// slot-addressed form used by replica apply, redo replay, and
    /// transaction rollback — every path where the slot was chosen
    /// elsewhere and divergence must surface as an error, not a silent
    /// relocation.
    pub fn insert_at(&mut self, slot: Slot, row: Row) -> Result<()> {
        let row = self.def.schema.coerce_row(row)?;
        if let Some(k) = self.pk_of(&row) {
            if self.pk.contains_key(&k) {
                return Err(Error::Constraint(format!(
                    "duplicate primary key {k} in '{}'",
                    self.def.name
                )));
            }
        }
        while self.rows.len() <= slot {
            self.free.insert(self.rows.len());
            self.rows.push(None);
        }
        if self.rows[slot].is_some() {
            return Err(Error::Constraint(format!(
                "slot {slot} already occupied in '{}'",
                self.def.name
            )));
        }
        self.free.remove(&slot);
        self.place(slot, row);
        Ok(())
    }

    /// Read a row by slot.
    pub fn get(&self, slot: Slot) -> Option<&Row> {
        self.rows.get(slot).and_then(|r| r.as_ref())
    }

    /// Slot for a primary-key value.
    pub fn slot_by_pk(&self, key: i64) -> Option<Slot> {
        self.pk.get(&key).copied()
    }

    /// Candidate slots where `column == value`, using a secondary index if
    /// one exists. Returns `None` when the column is not indexed (caller
    /// must scan); the borrowed slice may contain hash-collision false
    /// positives, so callers still re-check the predicate. Borrowing (rather
    /// than cloning the bucket) matters on the claim loop, where the `READY`
    /// bucket can span most of a partition.
    pub fn slots_by_index(&self, col_idx: usize, value: &Value) -> Option<&[Slot]> {
        let (_, map) = self.secondary.iter().find(|(ci, _)| *ci == col_idx)?;
        Some(match map.get(&value.hash_key()) {
            Some(v) => v.as_slice(),
            None => &[],
        })
    }

    /// Overwrite the row at `slot` with a validated new row.
    pub fn update(&mut self, slot: Slot, new_row: Row) -> Result<()> {
        self.update_in_place(slot, new_row).map(|_| ())
    }

    /// Overwrite the row at `slot` and hand the displaced old row back to
    /// the caller **without cloning it** (the caller typically keeps it as
    /// undo state and for change detection). Secondary indexes are only
    /// rewritten for columns whose value actually changed — the fast DML
    /// path's point updates flip `status` and leave the rest alone.
    pub fn update_in_place(&mut self, slot: Slot, new_row: Row) -> Result<Row> {
        let new_row = self.def.schema.coerce_row(new_row)?;
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("update of dead slot {slot}")))?;
        // Primary key immutability keeps the index trivially consistent;
        // the workflow engine never rewrites task ids.
        if let (Some(a), Some(b)) = (self.pk_of(&old), self.pk_of(&new_row)) {
            if a != b {
                self.rows[slot] = Some(old);
                return Err(Error::Constraint(format!(
                    "primary key is immutable ({a} -> {b})"
                )));
            }
        }
        self.index_update(slot, &old, &new_row);
        self.approx_bytes = self.approx_bytes - old.approx_bytes() + new_row.approx_bytes();
        self.rows[slot] = Some(new_row);
        self.version += 1;
        Ok(old)
    }

    /// Delete the row at `slot`; returns the removed row.
    pub fn delete(&mut self, slot: Slot) -> Result<Row> {
        let old = self
            .rows
            .get_mut(slot)
            .and_then(|r| r.take())
            .ok_or_else(|| Error::Constraint(format!("delete of dead slot {slot}")))?;
        if let Some(k) = self.pk_of(&old) {
            self.pk.remove(&k);
        }
        self.index_remove(slot, &old);
        self.approx_bytes -= old.approx_bytes();
        self.free.insert(slot);
        self.live -= 1;
        self.version += 1;
        Ok(old)
    }

    /// Apply one redo record (replica catch-up / WAL replay), idempotently:
    ///
    /// - a record at or below the current version was already applied —
    ///   skipped, `Ok(false)`;
    /// - the next record in sequence (`lsn == version + 1`) applies and
    ///   advances the version to exactly `lsn`, `Ok(true)`;
    /// - a gap (`lsn > version + 1`) is unrecoverable from this stream —
    ///   the caller falls back to a snapshot re-seed;
    /// - a record from an **older epoch** than this replica's fence is
    ///   rejected outright: a stale rejoiner replaying its pre-crash log
    ///   must not clobber rows committed after the promotion it missed.
    pub fn apply_redo(&mut self, rec: &WalRecord) -> Result<bool> {
        if rec.lsn <= self.version {
            // already applied (idempotent skip) — checked before the fence
            // so a late duplicate from an old epoch cannot halt replay
            return Ok(false);
        }
        if rec.epoch < self.epoch {
            return Err(Error::TxnAborted(format!(
                "fenced: redo record epoch {} below replica epoch {} on '{}'",
                rec.epoch, self.epoch, self.def.name
            )));
        }
        if rec.lsn > self.version + 1 {
            return Err(Error::TxnAborted(format!(
                "redo gap on '{}': have lsn {}, next record is {}",
                self.def.name, self.version, rec.lsn
            )));
        }
        match &rec.op {
            LogOp::Insert { slot, row, .. } => self.insert_at(*slot, row.as_ref().clone())?,
            LogOp::Update { slot, row, .. } => self.update(*slot, row.as_ref().clone())?,
            LogOp::Delete { slot, .. } => {
                self.delete(*slot)?;
            }
        }
        debug_assert_eq!(self.version, rec.lsn, "mutations bump the version by exactly one");
        Ok(true)
    }

    /// Iterate live `(slot, row)` pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (Slot, &Row)> {
        self.rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|row| (i, row)))
    }

    /// Deep copy of all live rows (legacy checkpointing / bulk export).
    pub fn snapshot_rows(&self) -> Vec<Row> {
        self.iter().map(|(_, r)| r.clone()).collect()
    }

    /// Deep, **slot-preserving** copy: `(slab capacity, live rows with
    /// their slots)`. This is the replica-seeding format — reloading it via
    /// [`PartitionStore::load_slotted`] reproduces the slab layout (holes
    /// included) so slot-addressed redo keeps applying cleanly afterwards.
    pub fn snapshot_slotted(&self) -> (usize, Vec<(Slot, Row)>) {
        (self.rows.len(), self.iter().map(|(s, r)| (s, r.clone())).collect())
    }

    /// Versioned snapshot of the live rows in slot order, shared via `Arc`.
    ///
    /// The rows are materialized at most once per partition version: repeat
    /// readers between mutations get the same `Arc` back for the cost of a
    /// clone. Callers hold the partition's read latch only long enough to
    /// call this; query execution then proceeds against the immutable
    /// snapshot with **no partition lock held**, which is what keeps the
    /// steering analytics off the scheduler's 2PL critical path.
    pub fn snapshot(&self) -> Arc<Vec<Row>> {
        let mut g = self.snap.lock().unwrap();
        if let Some((v, rows)) = g.as_ref() {
            if *v == self.version {
                return rows.clone();
            }
        }
        let rows = Arc::new(self.snapshot_rows());
        *g = Some((self.version, rows.clone()));
        rows
    }

    /// Rebuild the store from a row list (compacting; legacy recovery and
    /// test seeding — replica seeding uses [`PartitionStore::load_slotted`]).
    ///
    /// Drops any cached snapshot: callers may assign `version`
    /// non-monotonically after a reload, so a stale cache entry could
    /// otherwise collide with a future version of different content.
    pub fn load_rows(&mut self, rows: Vec<Row>) -> Result<()> {
        self.wipe();
        for r in rows {
            self.insert(r)?;
        }
        Ok(())
    }

    /// Rebuild the store from a slot-preserving snapshot (replica seeding,
    /// checkpoint load): the slab is sized to `cap` and every hole the
    /// source had — including trailing ones — is reproduced, so canonical
    /// slot allocation continues identically on both sides. The caller
    /// assigns `version` (and `epoch`) afterwards.
    pub fn load_slotted(&mut self, cap: usize, rows: Vec<(Slot, Row)>) -> Result<()> {
        self.wipe();
        for s in 0..cap {
            self.free.insert(s);
            self.rows.push(None);
        }
        for (slot, row) in rows {
            if slot >= cap {
                return Err(Error::Constraint(format!(
                    "slotted load: slot {slot} outside slab capacity {cap}"
                )));
            }
            self.insert_at(slot, row)?;
        }
        Ok(())
    }

    /// Reset to empty (shared by the bulk loaders).
    fn wipe(&mut self) {
        *self.snap.lock().unwrap() = None;
        self.rows.clear();
        self.free.clear();
        self.pk.clear();
        for (_, m) in &mut self.secondary {
            m.clear();
        }
        self.live = 0;
        self.approx_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::{ColumnType, Schema};

    fn store() -> PartitionStore {
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
            ("dur", ColumnType::Float),
        ]);
        let def = TableDef::new("wq", schema)
            .with_primary_key("taskid")
            .unwrap()
            .with_index("status")
            .unwrap();
        PartitionStore::new(Arc::new(def))
    }

    fn row(id: i64, w: i64, st: &str) -> Row {
        Row::new(vec![Value::Int(id), Value::Int(w), Value::str(st), Value::Float(1.0)])
    }

    #[test]
    fn insert_get_update_delete_cycle() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));

        p.update(s1, row(2, 0, "RUNNING")).unwrap();
        assert_eq!(p.get(s1).unwrap().values[2], Value::str("RUNNING"));

        let old = p.delete(s0).unwrap();
        assert_eq!(old.values[0], Value::Int(1));
        assert_eq!(p.len(), 1);
        assert!(p.get(s0).is_none());

        // slot reuse
        let s2 = p.insert(row(3, 1, "READY")).unwrap();
        assert_eq!(s2, s0);
    }

    #[test]
    fn canonical_allocation_takes_smallest_free_slot() {
        let mut p = store();
        for i in 0..5 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        // free slots {1, 3} in delete order 3, then 1
        p.delete(3).unwrap();
        p.delete(1).unwrap();
        // allocation is by slot number, not LIFO delete order
        assert_eq!(p.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(p.insert(row(11, 0, "READY")).unwrap(), 3);
        assert_eq!(p.insert(row(12, 0, "READY")).unwrap(), 5);
    }

    #[test]
    fn insert_at_reconstructs_exact_layout() {
        let mut p = store();
        p.insert_at(2, row(1, 0, "READY")).unwrap();
        assert_eq!(p.slab_cap(), 3, "slab grew to cover the slot");
        assert_eq!(p.len(), 1);
        // slots 0 and 1 are holes; canonical allocation fills them first
        assert_eq!(p.insert(row(2, 0, "READY")).unwrap(), 0);
        assert_eq!(p.insert(row(3, 0, "READY")).unwrap(), 1);
        // occupied slot is a hard error
        assert!(p.insert_at(2, row(9, 0, "READY")).is_err());
        // duplicate PK caught before any slab mutation
        assert!(p.insert_at(7, row(1, 0, "READY")).is_err());
        assert_eq!(p.slab_cap(), 3);
    }

    #[test]
    fn pk_uniqueness_and_lookup() {
        let mut p = store();
        p.insert(row(7, 0, "READY")).unwrap();
        assert!(matches!(p.insert(row(7, 1, "READY")), Err(Error::Constraint(_))));
        let slot = p.slot_by_pk(7).unwrap();
        assert_eq!(p.get(slot).unwrap().values[1], Value::Int(0));
        assert!(p.slot_by_pk(99).is_none());
    }

    #[test]
    fn pk_is_immutable_via_update() {
        let mut p = store();
        let s = p.insert(row(1, 0, "READY")).unwrap();
        assert!(p.update(s, row(2, 0, "READY")).is_err());
    }

    #[test]
    fn secondary_index_tracks_mutations() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        p.insert(row(3, 0, "RUNNING")).unwrap();
        let status_ci = 2;
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready.len(), 2);
        assert!(ready.contains(&s0) && ready.contains(&s1));

        p.update(s0, row(1, 0, "FINISHED")).unwrap();
        let ready = p.slots_by_index(status_ci, &Value::str("READY")).unwrap();
        assert_eq!(ready, vec![s1]);
        let fin = p.slots_by_index(status_ci, &Value::str("FINISHED")).unwrap();
        assert_eq!(fin, vec![s0]);

        p.delete(s1).unwrap();
        assert!(p.slots_by_index(status_ci, &Value::str("READY")).unwrap().is_empty());
        // unindexed column -> None
        assert!(p.slots_by_index(0, &Value::Int(1)).is_none());
    }

    #[test]
    fn snapshot_and_reload() {
        let mut p = store();
        for i in 0..10 {
            p.insert(row(i, i % 3, "READY")).unwrap();
        }
        p.delete(p.slot_by_pk(4).unwrap()).unwrap();
        let snap = p.snapshot_rows();
        assert_eq!(snap.len(), 9);

        let mut q = store();
        q.load_rows(snap).unwrap();
        assert_eq!(q.len(), 9);
        assert!(q.slot_by_pk(4).is_none());
        assert!(q.slot_by_pk(5).is_some());
        // indexes rebuilt
        assert_eq!(q.slots_by_index(2, &Value::str("READY")).unwrap().len(), 9);
    }

    #[test]
    fn slotted_snapshot_reproduces_holes_and_allocation() {
        let mut p = store();
        for i in 0..6 {
            p.insert(row(i, 0, "READY")).unwrap();
        }
        p.delete(1).unwrap();
        p.delete(4).unwrap();
        p.delete(5).unwrap(); // trailing hole
        let (cap, rows) = p.snapshot_slotted();
        assert_eq!(cap, 6);
        assert_eq!(rows.len(), 3);

        let mut q = store();
        q.load_slotted(cap, rows).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.slab_cap(), 6);
        // both replicas now make identical canonical choices
        assert_eq!(p.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(q.insert(row(10, 0, "READY")).unwrap(), 1);
        assert_eq!(p.insert(row(11, 0, "READY")).unwrap(), 4);
        assert_eq!(q.insert(row(11, 0, "READY")).unwrap(), 4);
        // out-of-cap slot rejected
        let mut r = store();
        assert!(r.load_slotted(2, vec![(5, row(1, 0, "X"))]).is_err());
    }

    #[test]
    fn apply_redo_is_idempotent_and_gap_checked() {
        let mut primary = store();
        let mut replica = store();
        let mut recs: Vec<WalRecord> = Vec::new();
        for i in 0..4 {
            let slot = primary.insert(row(i, 0, "READY")).unwrap();
            recs.push(WalRecord {
                lsn: primary.version,
                epoch: 0,
                op: LogOp::Insert {
                    table: "wq".into(),
                    pidx: 0,
                    slot,
                    row: Arc::new(primary.get(slot).unwrap().clone()),
                },
            });
        }
        let s1 = primary.slot_by_pk(1).unwrap();
        primary.delete(s1).unwrap();
        recs.push(WalRecord {
            lsn: primary.version,
            epoch: 0,
            op: LogOp::Delete { table: "wq".into(), pidx: 0, slot: s1 },
        });
        for rec in &recs {
            assert!(replica.apply_redo(rec).unwrap());
        }
        assert_eq!(replica.version, primary.version);
        assert_eq!(replica.len(), primary.len());
        // replaying the same records is a no-op
        for rec in &recs {
            assert!(!replica.apply_redo(rec).unwrap());
        }
        assert_eq!(replica.version, primary.version);
        // a gap is an error, not silent corruption
        let gap = WalRecord {
            lsn: primary.version + 5,
            epoch: 0,
            op: LogOp::Delete { table: "wq".into(), pidx: 0, slot: 0 },
        };
        assert!(replica.apply_redo(&gap).is_err());
    }

    #[test]
    fn apply_redo_fences_stale_epochs() {
        let mut p = store();
        p.epoch = 2;
        let stale = WalRecord {
            lsn: 1,
            epoch: 1,
            op: LogOp::Insert {
                table: "wq".into(),
                pidx: 0,
                slot: 0,
                row: Arc::new(row(1, 0, "READY")),
            },
        };
        let e = p.apply_redo(&stale);
        assert!(e.is_err(), "stale-epoch record must be fenced");
        assert_eq!(p.len(), 0, "fenced record must not touch the store");
        let current = WalRecord { epoch: 2, ..stale };
        assert!(p.apply_redo(&current).unwrap());
    }

    #[test]
    fn update_in_place_returns_old_row_and_skips_unchanged_indexes() {
        let mut p = store();
        let s0 = p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.insert(row(2, 0, "READY")).unwrap();
        let status_ci = 2;
        // rewriting an unindexed column must leave the status bucket's
        // order untouched (no remove+reinsert churn)
        let before: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        let old = p
            .update_in_place(s0, Row::new(vec![
                Value::Int(1),
                Value::Int(7),
                Value::str("READY"),
                Value::Float(2.0),
            ]))
            .unwrap();
        assert_eq!(old.values[1], Value::Int(0), "old row handed back");
        assert_eq!(old.values[3], Value::Float(1.0));
        let after: Vec<Slot> =
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap().to_vec();
        assert_eq!(before, after, "unchanged index key must not be rewritten");
        // changing the indexed column still moves the slot between buckets
        p.update_in_place(s0, row(1, 7, "RUNNING")).unwrap();
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("READY")).unwrap(),
            &[s1][..]
        );
        assert_eq!(
            p.slots_by_index(status_ci, &Value::str("RUNNING")).unwrap(),
            &[s0][..]
        );
        // pk immutability enforced, store left intact on the error
        assert!(p.update_in_place(s0, row(9, 7, "RUNNING")).is_err());
        assert_eq!(p.get(s0).unwrap().values[0], Value::Int(1));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn byte_accounting_moves_with_rows() {
        let mut p = store();
        assert_eq!(p.approx_bytes(), 0);
        let s = p.insert(row(1, 0, "READY")).unwrap();
        let b1 = p.approx_bytes();
        assert!(b1 > 0);
        p.update(s, row(1, 0, "a-much-longer-status-string")).unwrap();
        assert!(p.approx_bytes() > b1);
        p.delete(s).unwrap();
        assert_eq!(p.approx_bytes(), 0);
    }

    #[test]
    fn snapshot_is_cached_per_version() {
        let mut p = store();
        p.insert(row(1, 0, "READY")).unwrap();
        let s1 = p.snapshot();
        let s2 = p.snapshot();
        assert!(Arc::ptr_eq(&s1, &s2), "unchanged partition must reuse the snapshot");
        assert_eq!(s1.len(), 1);
        p.insert(row(2, 0, "READY")).unwrap();
        let s3 = p.snapshot();
        assert!(!Arc::ptr_eq(&s1, &s3), "mutation must invalidate the cache");
        assert_eq!(s3.len(), 2);
        assert_eq!(s1.len(), 1, "an already-taken snapshot stays immutable");
    }

    #[test]
    fn version_bumps_on_every_mutation() {
        let mut p = store();
        let v0 = p.version;
        let s = p.insert(row(1, 0, "READY")).unwrap();
        p.update(s, row(1, 0, "RUNNING")).unwrap();
        p.delete(s).unwrap();
        assert_eq!(p.version, v0 + 3);
    }
}
