//! Prepared statements: parse once, bind typed values per execution.
//!
//! The engine's hot paths (`getREADYtasks`, the atomic claim,
//! `updateToFINISHED`, provenance inserts) repeat the same handful of
//! statements millions of times per run. Re-lexing and re-parsing the SQL
//! text for every call — and worse, splicing values into the text with
//! `format!`, which breaks on embedded quotes — is pure overhead on the
//! transaction-oriented path the paper says must stay thin (§3.1).
//!
//! A [`Prepared`] handle wraps an [`Arc<PreparedPlan>`]: the statement is
//! lexed, parsed and catalog-checked exactly once (see
//! [`DbCluster::prepare`](crate::storage::cluster::DbCluster::prepare),
//! which also serves handles out of a cluster-wide plan cache).
//! [`Prepared::bind`] substitutes the bound [`Value`]s for the `?`
//! placeholders in a fresh copy of the AST, so the executor — partition
//! pruning and index-probe selection included — sees ordinary literals.
//! Values never travel through SQL text, which closes the quoting hazard
//! by construction.
//!
//! Handles carry **no connection state**: a `Prepared` is just a parsed
//! plan, so the same handle keeps working across
//! [`Connector`](crate::storage::connector::Connector) failover and data
//! node promotion (see `tests/prepared_failover.rs`).
//!
//! Limitations of the placeholder grammar: `?` stands for a *value*
//! position only — table/column names, `LIMIT` counts, and `LIKE` patterns
//! cannot be parameters.

use crate::storage::dml_plan::DmlPlan;
use crate::storage::sql::ast::{Expr, SelectItem, SelectStmt, Statement};
use crate::storage::value::Value;
use crate::{Error, Result};
use std::sync::Arc;

/// Fixed width used when folding variable-length id sets into `IN (...)`
/// lists: callers prepare one statement with [`IN_CHUNK`] placeholders and
/// feed it [`padded_chunks`], so the plan cache holds a single plan per
/// statement shape instead of one per list length.
pub const IN_CHUNK: usize = 64;

/// `"?, ?, ..., ?"` with `n` placeholders (building the skeleton of an
/// `IN (...)` clause; the values themselves are always bound, never
/// interpolated).
pub fn in_placeholders(n: usize) -> String {
    let mut s = String::with_capacity(n * 3);
    for i in 0..n {
        if i > 0 {
            s.push_str(", ");
        }
        s.push('?');
    }
    s
}

/// Split `ids` into chunks of exactly `chunk` values, padding the last
/// chunk by repeating its final id. Duplicates are harmless inside an
/// `IN (...)` predicate, so a single fixed-width prepared statement covers
/// every list length.
pub fn padded_chunks(ids: &[i64], chunk: usize) -> Vec<Vec<Value>> {
    assert!(chunk > 0, "chunk width must be positive");
    let mut out = Vec::new();
    for group in ids.chunks(chunk) {
        let mut vals: Vec<Value> = group.iter().map(|i| Value::Int(*i)).collect();
        if let Some(last) = vals.last().cloned() {
            while vals.len() < chunk {
                vals.push(last.clone());
            }
            out.push(vals);
        }
    }
    out
}

/// The immutable, shareable product of preparing one statement.
pub struct PreparedPlan {
    /// Original statement text (plan-cache key, diagnostics).
    pub sql: String,
    /// Parsed AST with `Expr::Param` placeholders left in place.
    pub stmt: Statement,
    /// Number of `?` placeholders.
    pub params: usize,
    /// EXPLAIN-style plan summary (see [`Prepared::describe`]), rendered
    /// at prepare time — against the live catalog when prepared through
    /// `DbCluster::prepare`, without partition facts otherwise.
    pub describe: String,
    /// Compiled physical plan for fast point-DML shapes (see
    /// [`crate::storage::dml_plan`]); `None` means every execution takes
    /// the interpreted path. Compiled against the live catalog by
    /// `DbCluster::prepare`; plans built outside a cluster have none.
    pub dml: Option<DmlPlan>,
}

impl PreparedPlan {
    /// Build a plan outside a cluster (tests, offline tooling): the plan
    /// summary is rendered without catalog access, so partition counts and
    /// pruning targets read as unknown and no fast DML plan is compiled.
    pub fn new(sql: String, stmt: Statement, params: usize) -> PreparedPlan {
        let describe = crate::query::plan::explain(&stmt, |_| None);
        PreparedPlan { sql, stmt, params, describe, dml: None }
    }
}

/// A prepared-statement handle. Cheap to clone; independent of any
/// connector or data node, so it survives failover unchanged.
#[derive(Clone)]
pub struct Prepared {
    plan: Arc<PreparedPlan>,
}

impl Prepared {
    pub fn from_plan(plan: Arc<PreparedPlan>) -> Prepared {
        Prepared { plan }
    }

    /// Statement text this handle was prepared from.
    pub fn sql(&self) -> &str {
        &self.plan.sql
    }

    /// Number of `?` placeholders to bind.
    pub fn param_count(&self) -> usize {
        self.plan.params
    }

    /// The cached parse (placeholders still in place).
    pub fn statement(&self) -> &Statement {
        &self.plan.stmt
    }

    /// The compiled fast physical plan, when this statement fits one of the
    /// point-DML shapes (see [`crate::storage::dml_plan`]). The cluster's
    /// `exec_prepared` consults this to skip the SQL interpreter entirely;
    /// `None` means every execution binds and runs interpreted.
    pub fn fast_plan(&self) -> Option<&DmlPlan> {
        self.plan.dml.as_ref()
    }

    /// EXPLAIN-style description of how the engine will execute this
    /// statement: chosen path (scatter-gather aggregate, scatter scan,
    /// snapshot-join, or centralized 2PL), the aggregates pushed down to
    /// partitions, group keys, and partition pruning. Debugging aid — see
    /// DESIGN.md §"The scatter-gather query engine" for examples.
    pub fn describe(&self) -> &str {
        &self.plan.describe
    }

    /// Bind one value per placeholder, producing an executable statement.
    pub fn bind(&self, params: &[Value]) -> Result<Statement> {
        if params.len() != self.plan.params {
            return Err(Error::Type(format!(
                "statement wants {} parameters, got {} ({})",
                self.plan.params,
                params.len(),
                self.plan.sql
            )));
        }
        subst_stmt(&self.plan.stmt, params)
    }

    /// Batched bind for bulk inserts: the plan must be an `INSERT` with a
    /// single row template; each entry of `rows` binds one copy of that
    /// template, yielding a single atomic multi-row insert.
    pub fn bind_batch(&self, rows: &[Vec<Value>]) -> Result<Statement> {
        let Statement::Insert { table, columns, values } = &self.plan.stmt else {
            return Err(Error::Type(format!(
                "bind_batch needs an INSERT statement ({})",
                self.plan.sql
            )));
        };
        if values.len() != 1 {
            return Err(Error::Type(format!(
                "bind_batch needs a single row template, found {} rows ({})",
                values.len(),
                self.plan.sql
            )));
        }
        if rows.is_empty() {
            return Err(Error::Type("bind_batch with zero rows".into()));
        }
        let template = &values[0];
        let mut bound = Vec::with_capacity(rows.len());
        for row in rows {
            if row.len() != self.plan.params {
                return Err(Error::Type(format!(
                    "row binds {} parameters, template wants {} ({})",
                    row.len(),
                    self.plan.params,
                    self.plan.sql
                )));
            }
            bound.push(
                template
                    .iter()
                    .map(|e| subst_expr(e, row))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        Ok(Statement::Insert {
            table: table.clone(),
            columns: columns.clone(),
            values: bound,
        })
    }
}

/// Replace every `Expr::Param` in `stmt` with the matching bound literal.
fn subst_stmt(stmt: &Statement, params: &[Value]) -> Result<Statement> {
    Ok(match stmt {
        Statement::Select(s) => Statement::Select(subst_select(s, params)?),
        Statement::Insert { table, columns, values } => Statement::Insert {
            table: table.clone(),
            columns: columns.clone(),
            values: values
                .iter()
                .map(|row| row.iter().map(|e| subst_expr(e, params)).collect())
                .collect::<Result<Vec<_>>>()?,
        },
        Statement::Update { table, sets, where_, order_by, limit, returning } => {
            Statement::Update {
                table: table.clone(),
                sets: sets
                    .iter()
                    .map(|(c, e)| Ok((c.clone(), subst_expr(e, params)?)))
                    .collect::<Result<Vec<_>>>()?,
                where_: subst_opt(where_, params)?,
                order_by: subst_order(order_by, params)?,
                limit: *limit,
                returning: match returning {
                    Some(items) => Some(subst_items(items, params)?),
                    None => None,
                },
            }
        }
        Statement::Delete { table, where_ } => Statement::Delete {
            table: table.clone(),
            where_: subst_opt(where_, params)?,
        },
        Statement::CreateTable { .. } => stmt.clone(),
    })
}

fn subst_select(s: &SelectStmt, params: &[Value]) -> Result<SelectStmt> {
    Ok(SelectStmt {
        items: subst_items(&s.items, params)?,
        from: s.from.clone(),
        joins: s
            .joins
            .iter()
            .map(|j| {
                Ok(crate::storage::sql::ast::Join {
                    table: j.table.clone(),
                    on: subst_expr(&j.on, params)?,
                    left_outer: j.left_outer,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        where_: subst_opt(&s.where_, params)?,
        group_by: s
            .group_by
            .iter()
            .map(|e| subst_expr(e, params))
            .collect::<Result<Vec<_>>>()?,
        having: subst_opt(&s.having, params)?,
        order_by: subst_order(&s.order_by, params)?,
        limit: s.limit,
    })
}

fn subst_items(items: &[SelectItem], params: &[Value]) -> Result<Vec<SelectItem>> {
    items
        .iter()
        .map(|it| {
            Ok(match it {
                SelectItem::Wildcard(q) => SelectItem::Wildcard(q.clone()),
                SelectItem::Expr { expr, alias } => SelectItem::Expr {
                    expr: subst_expr(expr, params)?,
                    alias: alias.clone(),
                },
            })
        })
        .collect()
}

fn subst_opt(e: &Option<Expr>, params: &[Value]) -> Result<Option<Expr>> {
    match e {
        Some(x) => Ok(Some(subst_expr(x, params)?)),
        None => Ok(None),
    }
}

fn subst_order(order: &[(Expr, bool)], params: &[Value]) -> Result<Vec<(Expr, bool)>> {
    order
        .iter()
        .map(|(e, asc)| Ok((subst_expr(e, params)?, *asc)))
        .collect()
}

/// Structural copy of `e` with `Param(i)` replaced by `Lit(params[i])`.
fn subst_expr(e: &Expr, params: &[Value]) -> Result<Expr> {
    Ok(match e {
        Expr::Param(i) => {
            let v = params.get(*i).ok_or_else(|| {
                Error::Type(format!("parameter ?{i} out of range ({} bound)", params.len()))
            })?;
            Expr::Lit(v.clone())
        }
        Expr::Lit(_) | Expr::Col { .. } => e.clone(),
        Expr::Unary(op, x) => Expr::Unary(*op, Box::new(subst_expr(x, params)?)),
        Expr::Binary(op, a, b) => Expr::Binary(
            *op,
            Box::new(subst_expr(a, params)?),
            Box::new(subst_expr(b, params)?),
        ),
        Expr::Func { name, args } => Expr::Func {
            name: name.clone(),
            args: args
                .iter()
                .map(|a| subst_expr(a, params))
                .collect::<Result<Vec<_>>>()?,
        },
        Expr::Agg { func, arg, distinct } => Expr::Agg {
            func: *func,
            arg: match arg {
                Some(a) => Some(Box::new(subst_expr(a, params)?)),
                None => None,
            },
            distinct: *distinct,
        },
        Expr::InList { expr, list, negated } => Expr::InList {
            expr: Box::new(subst_expr(expr, params)?),
            list: list
                .iter()
                .map(|a| subst_expr(a, params))
                .collect::<Result<Vec<_>>>()?,
            negated: *negated,
        },
        Expr::Between { expr, lo, hi, negated } => Expr::Between {
            expr: Box::new(subst_expr(expr, params)?),
            lo: Box::new(subst_expr(lo, params)?),
            hi: Box::new(subst_expr(hi, params)?),
            negated: *negated,
        },
        Expr::IsNull { expr, negated } => Expr::IsNull {
            expr: Box::new(subst_expr(expr, params)?),
            negated: *negated,
        },
        Expr::Like { expr, pattern, negated } => Expr::Like {
            expr: Box::new(subst_expr(expr, params)?),
            pattern: pattern.clone(),
            negated: *negated,
        },
        Expr::Case { arms, else_ } => Expr::Case {
            arms: arms
                .iter()
                .map(|(c, v)| Ok((subst_expr(c, params)?, subst_expr(v, params)?)))
                .collect::<Result<Vec<_>>>()?,
            else_: match else_ {
                Some(x) => Some(Box::new(subst_expr(x, params)?)),
                None => None,
            },
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::sql::parser::parse_prepared;

    fn prep(sql: &str) -> Prepared {
        let (stmt, params) = parse_prepared(sql).unwrap();
        Prepared::from_plan(Arc::new(PreparedPlan::new(sql.to_string(), stmt, params)))
    }

    #[test]
    fn bind_replaces_placeholders_with_literals() {
        let p = prep("SELECT a FROM t WHERE b = ? AND s = ?");
        assert_eq!(p.param_count(), 2);
        let stmt = p.bind(&[Value::Int(7), Value::str("it's")]).unwrap();
        match stmt {
            Statement::Select(s) => {
                let w = s.where_.unwrap();
                let lits: Vec<&Expr> = w.conjuncts();
                assert!(lits.iter().any(|c| matches!(
                    c,
                    Expr::Binary(_, _, b) if **b == Expr::Lit(Value::Int(7))
                )));
                // the quoted string arrives intact, no escaping involved
                assert!(lits.iter().any(|c| matches!(
                    c,
                    Expr::Binary(_, _, b) if **b == Expr::Lit(Value::str("it's"))
                )));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bind_checks_arity() {
        let p = prep("SELECT a FROM t WHERE b = ?");
        assert!(p.bind(&[]).is_err());
        assert!(p.bind(&[Value::Int(1), Value::Int(2)]).is_err());
        assert!(p.bind(&[Value::Int(1)]).is_ok());
    }

    #[test]
    fn bind_batch_expands_insert_template() {
        let p = prep("INSERT INTO t (a, b, d) VALUES (?, ?, 'out')");
        let stmt = p
            .bind_batch(&[
                vec![Value::Int(1), Value::str("x")],
                vec![Value::Int(2), Value::str("y")],
            ])
            .unwrap();
        match stmt {
            Statement::Insert { values, .. } => {
                assert_eq!(values.len(), 2);
                assert_eq!(values[0][0], Expr::Lit(Value::Int(1)));
                assert_eq!(values[1][1], Expr::Lit(Value::str("y")));
                // the constant column survives in every expanded row
                assert_eq!(values[1][2], Expr::Lit(Value::str("out")));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bind_batch_rejects_non_insert_and_bad_rows() {
        let p = prep("UPDATE t SET a = ? WHERE b = ?");
        assert!(p.bind_batch(&[vec![Value::Int(1), Value::Int(2)]]).is_err());
        let p = prep("INSERT INTO t (a) VALUES (?)");
        assert!(p.bind_batch(&[]).is_err());
        assert!(p.bind_batch(&[vec![Value::Int(1), Value::Int(2)]]).is_err());
    }

    #[test]
    fn padded_chunks_fill_fixed_width() {
        let chunks = padded_chunks(&[1, 2, 3], 2);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0], vec![Value::Int(1), Value::Int(2)]);
        assert_eq!(chunks[1], vec![Value::Int(3), Value::Int(3)]);
        assert!(padded_chunks(&[], 4).is_empty());
        assert_eq!(in_placeholders(3), "?, ?, ?");
        assert_eq!(in_placeholders(0), "");
    }
}
