//! Table definitions: schema + partitioning + keys.

use crate::storage::value::{ColumnType, Schema, Value};
use crate::{Error, Result};
use std::sync::Arc;

/// How a table's rows are spread over partitions.
#[derive(Clone, Debug, PartialEq)]
pub enum Partitioning {
    /// Single partition (small catalog-style relations: activities,
    /// workflows, nodes).
    Single,
    /// Hash on one integer column into `n` partitions. SchalaDB's WQ design:
    /// hash on `worker_id` with `n = W` so each worker's lookups touch
    /// exactly one partition (paper §3.2).
    Hash { column: String, partitions: usize },
}

/// Definition of one table.
#[derive(Clone, Debug)]
pub struct TableDef {
    pub name: String,
    pub schema: Arc<Schema>,
    pub partitioning: Partitioning,
    /// Optional integer primary key column; maintained as a hash index in
    /// every partition and enforced unique *within* the table.
    pub primary_key: Option<String>,
    /// Secondary (non-unique) indexed columns per partition.
    pub indexes: Vec<String>,
    /// Congruence-class routing after **online partition splits**
    /// (`DbCluster::split_partition`). Empty until the first split — the
    /// uniform `key mod partitions` rule applies. Once populated,
    /// `split_classes[i] = (modulus, residue)` means physical partition `i`
    /// owns exactly the keys with `key mod modulus == residue`; splitting a
    /// partition halves its class (`(m, r)` → `(2m, r)` kept in place and
    /// `(2m, r + m)` appended as a new partition index), so the classes
    /// always stay disjoint and cover every key.
    pub split_classes: Vec<(i64, i64)>,
}

impl TableDef {
    pub fn new(name: impl Into<String>, schema: Schema) -> TableDef {
        TableDef {
            name: name.into(),
            schema: Arc::new(schema),
            partitioning: Partitioning::Single,
            primary_key: None,
            indexes: vec![],
            split_classes: vec![],
        }
    }

    /// Declare hash partitioning on an integer column.
    pub fn partition_by_hash(mut self, column: &str, partitions: usize) -> Result<TableDef> {
        let col = self
            .schema
            .column(column)
            .ok_or_else(|| Error::Catalog(format!("partition column '{column}' not in schema")))?;
        if col.ty != ColumnType::Int {
            return Err(Error::Catalog(format!(
                "partition column '{column}' must be INT, is {}",
                col.ty.name()
            )));
        }
        if partitions == 0 {
            return Err(Error::Catalog("partitions must be >= 1".into()));
        }
        self.partitioning = Partitioning::Hash { column: column.into(), partitions };
        Ok(self)
    }

    pub fn with_primary_key(mut self, column: &str) -> Result<TableDef> {
        let col = self
            .schema
            .column(column)
            .ok_or_else(|| Error::Catalog(format!("pk column '{column}' not in schema")))?;
        if col.ty != ColumnType::Int {
            return Err(Error::Catalog("primary key must be INT".into()));
        }
        self.primary_key = Some(column.into());
        Ok(self)
    }

    pub fn with_index(mut self, column: &str) -> Result<TableDef> {
        if self.schema.column(column).is_none() {
            return Err(Error::Catalog(format!("index column '{column}' not in schema")));
        }
        self.indexes.push(column.into());
        Ok(self)
    }

    /// Number of partitions (post-split classes included).
    pub fn num_partitions(&self) -> usize {
        if !self.split_classes.is_empty() {
            return self.split_classes.len();
        }
        match &self.partitioning {
            Partitioning::Single => 1,
            Partitioning::Hash { partitions, .. } => *partitions,
        }
    }

    /// The congruence class `(modulus, residue)` of physical partition
    /// `pidx`: its rows are exactly the keys with
    /// `key mod modulus == residue`. Before any split this is the uniform
    /// `(partitions, pidx)`; `None` for single-partition tables or an
    /// out-of-range index.
    pub fn partition_class(&self, pidx: usize) -> Option<(i64, i64)> {
        if !self.split_classes.is_empty() {
            return self.split_classes.get(pidx).copied();
        }
        match &self.partitioning {
            Partitioning::Single => None,
            Partitioning::Hash { partitions, .. } if pidx < *partitions => {
                Some((*partitions as i64, pidx as i64))
            }
            Partitioning::Hash { .. } => None,
        }
    }

    /// Derive the definition after splitting partition `pidx` in two: the
    /// old index keeps the keys with `key mod 2m == r` and a **new
    /// partition index** (appended, `num_partitions()` of the old def)
    /// takes `key mod 2m == r + m`. Routing state only — moving the rows
    /// is the cluster's job (`DbCluster::split_partition`).
    pub fn split_partition(&self, pidx: usize) -> Result<TableDef> {
        let Partitioning::Hash { .. } = &self.partitioning else {
            return Err(Error::Catalog(format!(
                "table '{}' is single-partition; only hash-partitioned tables split",
                self.name
            )));
        };
        let n = self.num_partitions();
        if pidx >= n {
            return Err(Error::Catalog(format!(
                "partition {pidx} out of range for '{}' ({n} partitions)",
                self.name
            )));
        }
        let mut classes: Vec<(i64, i64)> = if self.split_classes.is_empty() {
            (0..n as i64).map(|r| (n as i64, r)).collect()
        } else {
            self.split_classes.clone()
        };
        let (m, r) = classes[pidx];
        let m2 = m.checked_mul(2).ok_or_else(|| {
            Error::Catalog(format!("partition {pidx} of '{}' cannot split further", self.name))
        })?;
        classes[pidx] = (m2, r);
        classes.push((m2, r + m));
        let mut def = self.clone();
        def.split_classes = classes;
        Ok(def)
    }

    /// Schema index of the partition column, if hash-partitioned.
    pub fn partition_col_idx(&self) -> Option<usize> {
        match &self.partitioning {
            Partitioning::Single => None,
            Partitioning::Hash { column, .. } => self.schema.index_of(column),
        }
    }

    /// Partition index for a row (by its partition-column value).
    pub fn partition_of_row(&self, row: &[Value]) -> Result<usize> {
        match self.partition_col_idx() {
            None => Ok(0),
            Some(ci) => match &row[ci] {
                Value::Int(k) => Ok(self.partition_of_key(*k)),
                v => Err(Error::Type(format!(
                    "partition column of '{}' must be non-null INT, got {v}",
                    self.name
                ))),
            },
        }
    }

    /// Partition index for a key value.
    ///
    /// Identity-mod hashing, exactly the paper's design: `worker_id = i`
    /// lands in partition `i mod W`; with `partitions == W` each worker owns
    /// one partition. After an online split the key is routed to the unique
    /// congruence class containing it (see [`TableDef::split_classes`]).
    pub fn partition_of_key(&self, key: i64) -> usize {
        if !self.split_classes.is_empty() {
            for (i, (m, r)) in self.split_classes.iter().enumerate() {
                if key.rem_euclid(*m) == *r {
                    return i;
                }
            }
            // unreachable by construction (classes cover every residue);
            // keep a deterministic fallback rather than panicking
            return 0;
        }
        let n = self.num_partitions();
        (key.rem_euclid(n as i64)) as usize
    }

    /// Restore a post-split routing table verbatim (checkpoint recovery).
    /// Classes must be non-empty, disjoint, and cover every key; only
    /// trivially-checkable shape errors are rejected here.
    pub fn with_split_classes(mut self, classes: Vec<(i64, i64)>) -> Result<TableDef> {
        if !matches!(self.partitioning, Partitioning::Hash { .. }) {
            return Err(Error::Catalog(format!(
                "'{}': split classes require hash partitioning",
                self.name
            )));
        }
        if classes.is_empty() || classes.iter().any(|(m, r)| *m <= 0 || *r < 0 || r >= m) {
            return Err(Error::Catalog(format!("'{}': malformed split classes", self.name)));
        }
        self.split_classes = classes;
        Ok(self)
    }

    /// Schema index of the primary key column.
    pub fn pk_idx(&self) -> Option<usize> {
        self.primary_key.as_deref().and_then(|c| self.schema.index_of(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::Schema;

    fn def() -> TableDef {
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
        ]);
        TableDef::new("workqueue", schema)
            .partition_by_hash("workerid", 4)
            .unwrap()
            .with_primary_key("taskid")
            .unwrap()
            .with_index("status")
            .unwrap()
    }

    #[test]
    fn partition_routing_identity_mod() {
        let d = def();
        assert_eq!(d.num_partitions(), 4);
        assert_eq!(d.partition_of_key(0), 0);
        assert_eq!(d.partition_of_key(5), 1);
        assert_eq!(d.partition_of_key(-1), 3); // rem_euclid keeps it in range
        let row = vec![Value::Int(9), Value::Int(2), Value::str("READY")];
        assert_eq!(d.partition_of_row(&row).unwrap(), 2);
    }

    #[test]
    fn partition_column_must_be_int() {
        let schema = Schema::of(&[("s", ColumnType::Str)]);
        let e = TableDef::new("t", schema).partition_by_hash("s", 2);
        assert!(e.is_err());
    }

    #[test]
    fn unknown_columns_rejected() {
        let schema = Schema::of(&[("id", ColumnType::Int)]);
        assert!(TableDef::new("t", schema.clone()).partition_by_hash("nope", 2).is_err());
        assert!(TableDef::new("t", schema.clone()).with_primary_key("nope").is_err());
        assert!(TableDef::new("t", schema).with_index("nope").is_err());
    }

    #[test]
    fn null_partition_key_rejected() {
        let d = def();
        let row = vec![Value::Int(1), Value::Null, Value::str("READY")];
        assert!(d.partition_of_row(&row).is_err());
    }
}
