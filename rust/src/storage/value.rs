//! Typed values, rows, and schemas for the relational engine.

use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::cmp::Ordering;
use std::fmt;
use std::sync::Arc;

/// Column types supported by the engine. The WQ relation (paper Figure 3)
/// needs integers (ids, counters), floats (times, domain values), strings
/// (command lines, status) and booleans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ColumnType {
    Int,
    Float,
    Str,
    Bool,
}

impl ColumnType {
    pub fn name(self) -> &'static str {
        match self {
            ColumnType::Int => "INT",
            ColumnType::Float => "FLOAT",
            ColumnType::Str => "TEXT",
            ColumnType::Bool => "BOOL",
        }
    }

    pub fn parse(s: &str) -> Result<ColumnType> {
        match s.to_ascii_uppercase().as_str() {
            "INT" | "INTEGER" | "BIGINT" => Ok(ColumnType::Int),
            "FLOAT" | "DOUBLE" | "REAL" => Ok(ColumnType::Float),
            "TEXT" | "VARCHAR" | "STRING" => Ok(ColumnType::Str),
            "BOOL" | "BOOLEAN" => Ok(ColumnType::Bool),
            other => Err(Error::Parse(format!("unknown column type '{other}'"))),
        }
    }
}

/// A single typed value. `Str` is refcounted: command lines and workspace
/// paths are duplicated across many tasks and flow through scans, sorts and
/// joins — cloning must be O(1).
#[derive(Clone, Debug)]
pub enum Value {
    Null,
    Int(i64),
    Float(f64),
    Str(Arc<str>),
    Bool(bool),
}

impl Value {
    pub fn str(s: impl AsRef<str>) -> Value {
        Value::Str(Arc::from(s.as_ref()))
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn type_of(&self) -> Option<ColumnType> {
        match self {
            Value::Null => None,
            Value::Int(_) => Some(ColumnType::Int),
            Value::Float(_) => Some(ColumnType::Float),
            Value::Str(_) => Some(ColumnType::Str),
            Value::Bool(_) => Some(ColumnType::Bool),
        }
    }

    /// Numeric view (ints widen to float); `None` for non-numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// SQL three-valued-logic equality: any comparison with NULL is `None`.
    pub fn sql_eq(&self, other: &Value) -> Option<bool> {
        self.sql_cmp(other).map(|o| o == Ordering::Equal)
    }

    /// SQL comparison with numeric coercion between Int and Float.
    /// Cross-type comparisons (e.g. Str vs Int) are a type error at the
    /// expression layer; here they yield `None` like NULL.
    pub fn sql_cmp(&self, other: &Value) -> Option<Ordering> {
        use Value::*;
        match (self, other) {
            (Null, _) | (_, Null) => None,
            (Int(a), Int(b)) => Some(a.cmp(b)),
            (Str(a), Str(b)) => Some(a.as_ref().cmp(b.as_ref())),
            (Bool(a), Bool(b)) => Some(a.cmp(b)),
            _ => {
                let (a, b) = (self.as_f64()?, other.as_f64()?);
                a.partial_cmp(&b)
            }
        }
    }

    /// Total order for ORDER BY / index keys: NULLs first, then by type
    /// class, then by value. NaN sorts after all other floats.
    pub fn total_cmp(&self, other: &Value) -> Ordering {
        fn class(v: &Value) -> u8 {
            match v {
                Value::Null => 0,
                Value::Bool(_) => 1,
                Value::Int(_) | Value::Float(_) => 2,
                Value::Str(_) => 3,
            }
        }
        if let Some(o) = self.sql_cmp(other) {
            return o;
        }
        match (self, other) {
            (Value::Null, Value::Null) => Ordering::Equal,
            (Value::Float(a), Value::Float(b)) => a.total_cmp(b),
            (Value::Float(a), Value::Int(b)) => a.total_cmp(&(*b as f64)),
            (Value::Int(a), Value::Float(b)) => (*a as f64).total_cmp(b),
            _ => class(self).cmp(&class(other)),
        }
    }

    /// Hash key for group-by / hash-join. Floats with integral value hash
    /// like the equal Int so coercing joins group correctly.
    pub fn hash_key(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = rustc_hash::FxHasher::default();
        match self {
            Value::Null => 0u8.hash(&mut h),
            Value::Bool(b) => {
                1u8.hash(&mut h);
                b.hash(&mut h);
            }
            Value::Int(i) => {
                2u8.hash(&mut h);
                i.hash(&mut h);
            }
            Value::Float(f) => {
                if f.fract() == 0.0 && f.abs() < 9e15 {
                    2u8.hash(&mut h);
                    (*f as i64).hash(&mut h);
                } else {
                    3u8.hash(&mut h);
                    f.to_bits().hash(&mut h);
                }
            }
            Value::Str(s) => {
                4u8.hash(&mut h);
                s.hash(&mut h);
            }
        }
        h.finish()
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        self.total_cmp(other) == Ordering::Equal
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "NULL"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{:.1}", x)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write!(f, "{s}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// A column definition.
#[derive(Clone, Debug, PartialEq)]
pub struct Column {
    pub name: String,
    pub ty: ColumnType,
    pub nullable: bool,
}

/// Table schema: ordered columns + name→index map.
#[derive(Clone, Debug)]
pub struct Schema {
    pub columns: Vec<Column>,
    by_name: FxHashMap<String, usize>,
}

impl PartialEq for Schema {
    fn eq(&self, other: &Self) -> bool {
        self.columns == other.columns
    }
}

impl Schema {
    pub fn new(columns: Vec<Column>) -> Result<Schema> {
        let mut by_name = FxHashMap::default();
        for (i, c) in columns.iter().enumerate() {
            if by_name.insert(c.name.clone(), i).is_some() {
                return Err(Error::Catalog(format!("duplicate column '{}'", c.name)));
            }
        }
        Ok(Schema { columns, by_name })
    }

    /// Builder from `(name, type)` pairs; all columns nullable except as
    /// adjusted later. Convenience for tests and internal schemas.
    pub fn of(cols: &[(&str, ColumnType)]) -> Schema {
        Schema::new(
            cols.iter()
                .map(|(n, t)| Column { name: n.to_string(), ty: *t, nullable: true })
                .collect(),
        )
        .expect("static schema")
    }

    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn column(&self, name: &str) -> Option<&Column> {
        self.index_of(name).map(|i| &self.columns[i])
    }

    /// Validate a row against the schema: arity, types (with int→float
    /// widening), nullability.
    pub fn check_row(&self, row: &Row) -> Result<()> {
        if row.values.len() != self.columns.len() {
            return Err(Error::Type(format!(
                "row arity {} != schema arity {}",
                row.values.len(),
                self.columns.len()
            )));
        }
        for (v, c) in row.values.iter().zip(&self.columns) {
            match v {
                Value::Null => {
                    if !c.nullable {
                        return Err(Error::Constraint(format!(
                            "column '{}' is NOT NULL",
                            c.name
                        )));
                    }
                }
                v => {
                    let vt = v.type_of().unwrap();
                    let ok = vt == c.ty || (vt == ColumnType::Int && c.ty == ColumnType::Float);
                    if !ok {
                        return Err(Error::Type(format!(
                            "column '{}' expects {}, got {}",
                            c.name,
                            c.ty.name(),
                            vt.name()
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Coerce Int literals into Float columns so inserted rows are
    /// uniformly typed in storage.
    pub fn coerce_row(&self, mut row: Row) -> Result<Row> {
        self.check_row(&row)?;
        for (v, c) in row.values.iter_mut().zip(&self.columns) {
            if c.ty == ColumnType::Float {
                if let Value::Int(i) = v {
                    *v = Value::Float(*i as f64);
                }
            }
        }
        Ok(row)
    }
}

/// A row of values. Kept as a plain struct (not an alias) so we can hang
/// helpers off it and later add hidden columns without touching call sites.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Row {
    pub values: Vec<Value>,
}

impl Row {
    pub fn new(values: Vec<Value>) -> Row {
        Row { values }
    }

    pub fn get(&self, i: usize) -> &Value {
        &self.values[i]
    }

    /// Approximate in-memory footprint in bytes (for DB-size reporting,
    /// paper §5.1 "tens of MB for large workloads").
    pub fn approx_bytes(&self) -> usize {
        let mut n = std::mem::size_of::<Row>() + self.values.capacity() * std::mem::size_of::<Value>();
        for v in &self.values {
            if let Value::Str(s) = v {
                n += s.len();
            }
        }
        n
    }
}

impl From<Vec<Value>> for Row {
    fn from(values: Vec<Value>) -> Row {
        Row { values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sql_cmp_coerces_numerics() {
        assert_eq!(Value::Int(2).sql_cmp(&Value::Float(2.0)), Some(Ordering::Equal));
        assert_eq!(Value::Int(2).sql_cmp(&Value::Float(2.5)), Some(Ordering::Less));
        assert_eq!(Value::Null.sql_cmp(&Value::Int(1)), None);
        assert_eq!(Value::str("a").sql_cmp(&Value::str("b")), Some(Ordering::Less));
        assert_eq!(Value::str("a").sql_cmp(&Value::Int(1)), None);
    }

    #[test]
    fn total_cmp_orders_nulls_first() {
        let mut vs = vec![Value::Int(3), Value::Null, Value::Float(1.5), Value::str("x")];
        vs.sort_by(|a, b| a.total_cmp(b));
        assert!(vs[0].is_null());
        assert_eq!(vs[1], Value::Float(1.5));
        assert_eq!(vs[2], Value::Int(3));
        assert_eq!(vs[3], Value::str("x"));
    }

    #[test]
    fn hash_key_coerces_integral_floats() {
        assert_eq!(Value::Int(7).hash_key(), Value::Float(7.0).hash_key());
        assert_ne!(Value::Int(7).hash_key(), Value::Float(7.5).hash_key());
    }

    #[test]
    fn schema_checks_types_and_nulls() {
        let mut s = Schema::of(&[("id", ColumnType::Int), ("v", ColumnType::Float)]);
        s.columns[0].nullable = false;
        assert!(s.check_row(&Row::new(vec![Value::Int(1), Value::Float(2.0)])).is_ok());
        // int widens into float column
        assert!(s.check_row(&Row::new(vec![Value::Int(1), Value::Int(2)])).is_ok());
        // null into NOT NULL
        assert!(matches!(
            s.check_row(&Row::new(vec![Value::Null, Value::Null])),
            Err(Error::Constraint(_))
        ));
        // wrong type
        assert!(matches!(
            s.check_row(&Row::new(vec![Value::str("x"), Value::Null])),
            Err(Error::Type(_))
        ));
        // arity
        assert!(matches!(s.check_row(&Row::new(vec![])), Err(Error::Type(_))));
    }

    #[test]
    fn coerce_widens_int_literals() {
        let s = Schema::of(&[("v", ColumnType::Float)]);
        let r = s.coerce_row(Row::new(vec![Value::Int(3)])).unwrap();
        assert_eq!(r.values[0], Value::Float(3.0));
    }

    #[test]
    fn schema_rejects_duplicate_columns() {
        let cols = vec![
            Column { name: "a".into(), ty: ColumnType::Int, nullable: true },
            Column { name: "a".into(), ty: ColumnType::Int, nullable: true },
        ];
        assert!(Schema::new(cols).is_err());
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Float(2.0).to_string(), "2.0");
        assert_eq!(Value::Float(2.25).to_string(), "2.25");
        assert_eq!(Value::Null.to_string(), "NULL");
        assert_eq!(Value::str("hi").to_string(), "hi");
    }
}
