//! Availability management: heartbeat-style liveness watching plus the
//! promote/heal cycle.
//!
//! The mechanics (backup promotion, replica re-seeding) live on
//! [`DbCluster`]; this module packages them behind a watcher that the
//! engine runs periodically, mirroring how NDB's arbitrator reacts to
//! missed heartbeats.

use crate::storage::cluster::DbCluster;
use crate::Result;
use std::sync::Arc;

/// Outcome of one availability sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepReport {
    /// Dead data nodes observed.
    pub dead_nodes: usize,
    /// Backup replicas promoted to primary this sweep.
    pub promoted: usize,
    /// Stale replicas re-seeded from primaries this sweep.
    pub healed: usize,
}

/// Watches data-node liveness and repairs placement.
pub struct AvailabilityManager {
    cluster: Arc<DbCluster>,
    /// Cumulative counters across sweeps (monitoring).
    pub total_promoted: std::sync::atomic::AtomicUsize,
    pub total_healed: std::sync::atomic::AtomicUsize,
}

impl AvailabilityManager {
    pub fn new(cluster: Arc<DbCluster>) -> AvailabilityManager {
        AvailabilityManager {
            cluster,
            total_promoted: std::sync::atomic::AtomicUsize::new(0),
            total_healed: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// One sweep: count dead nodes, promote backups whose primary is dead,
    /// re-seed stale replicas where both sides are alive again.
    pub fn sweep(&self) -> Result<SweepReport> {
        let dead_nodes = (0..self.cluster.num_nodes() as u32)
            .filter(|i| self.cluster.node(*i).map_or(false, |n| !n.is_alive()))
            .count();
        let promoted = self.cluster.promote_dead_primaries();
        let healed = self.cluster.heal()?;
        self.total_promoted.fetch_add(promoted, std::sync::atomic::Ordering::Relaxed);
        self.total_healed.fetch_add(healed, std::sync::atomic::Ordering::Relaxed);
        Ok(SweepReport { dead_nodes, promoted, healed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::value::Value;

    fn cluster() -> Arc<DbCluster> {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        c
    }

    #[test]
    fn kill_promote_revive_heal_cycle() {
        let c = cluster();
        let am = AvailabilityManager::new(c.clone());

        // healthy sweep: nothing to do
        let r = am.sweep().unwrap();
        assert_eq!(r, SweepReport { dead_nodes: 0, promoted: 0, healed: 0 });

        // kill node 0: its primaries get promoted
        c.kill_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.dead_nodes, 1);
        assert!(r.promoted > 0);

        // data fully available during the outage
        let rs = c.query("SELECT COUNT(*) FROM t").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(20));
        // and writable (writes land on promoted primaries, with the backup
        // side degraded)
        c.execute("UPDATE t SET v = 99.0 WHERE id = 3").unwrap();

        // revive: heal re-seeds the stale replicas on node 0
        c.revive_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.healed > 0, "stale replicas on revived node must be re-seeded");

        // after heal, a second failure of the *other* node is survivable
        c.kill_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.promoted > 0);
        let rs = c.query("SELECT v FROM t WHERE id = 3").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Float(99.0));
    }

    #[test]
    fn cumulative_counters() {
        let c = cluster();
        let am = AvailabilityManager::new(c.clone());
        c.kill_node(1).unwrap();
        am.sweep().unwrap();
        // a write during the outage makes node 1's replicas stale, so the
        // post-revival sweep has something to heal
        c.execute("UPDATE t SET v = 1.0").unwrap();
        c.revive_node(1).unwrap();
        am.sweep().unwrap();
        assert!(am.total_promoted.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert!(am.total_healed.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
}
