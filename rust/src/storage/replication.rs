//! Availability management: heartbeat-style liveness watching plus the
//! promote / heal / **rejoin** cycle.
//!
//! The mechanics (backup promotion, replica re-seeding, the rejoin
//! catch-up and hand-off) live on [`DbCluster`]; this module packages them
//! behind a watcher that the engine runs periodically, mirroring how NDB's
//! arbitrator reacts to missed heartbeats and how a restarted NDB node
//! walks its node-recovery protocol before serving again.
//!
//! One sweep:
//!
//! 1. count dead nodes (monitoring);
//! 2. promote backups whose primary died (opens a new cluster epoch);
//! 3. heal stale-but-alive replicas (slot-preserving re-seed);
//! 4. drive every `Rejoining` node through catch-up: a few opportunistic
//!    redo-ship rounds (no serving-side write block), then the final cut
//!    that freezes each partition briefly, closes the remaining gap, and
//!    flips the node back to serving;
//! 5. on the configured cadence (`DurabilityConfig::checkpoint_every_sweeps`),
//!    cut incremental per-partition checkpoints on every serving node —
//!    the automatic counterpart of NDB's periodic local checkpoints, so
//!    WAL segments are truncated (and restart recovery stays bounded)
//!    without anyone calling `checkpoint_node` by hand.

use crate::obs::{Counter, Hist};
use crate::storage::checkpoint;
use crate::storage::cluster::DbCluster;
use crate::storage::datanode::NodeState;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// How many opportunistic catch-up rounds a sweep runs before the final
/// cut. Each round ships the tail that accumulated during the previous
/// one, so by the cut the remaining gap is whatever committed in the last
/// few microseconds.
const CATCHUP_ROUNDS: usize = 2;

/// Outcome of one availability sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepReport {
    /// Dead data nodes observed.
    pub dead_nodes: usize,
    /// Backup replicas promoted to primary this sweep.
    pub promoted: usize,
    /// Stale replicas re-seeded from primaries this sweep.
    pub healed: usize,
    /// Nodes observed in the rejoin state machine when the sweep started.
    pub rejoining: usize,
    /// Nodes whose rejoin completed this sweep (now serving again).
    pub rejoined: usize,
    /// Redo records shipped to rejoining nodes this sweep.
    pub shipped_ops: u64,
    /// Partitions that needed a full snapshot re-seed because the retained
    /// redo tail could not cover their gap.
    pub reseeded_parts: usize,
    /// Partition checkpoints (re)written by this sweep's cadence-driven
    /// cut (0 when the cadence is off, the sweep is off-cadence, or every
    /// partition checkpoint was already current).
    pub checkpointed: usize,
}

/// Watches data-node liveness and repairs placement.
pub struct AvailabilityManager {
    cluster: Arc<DbCluster>,
    /// Sweeps run so far (drives the checkpoint cadence).
    sweeps: AtomicUsize,
    /// Cumulative counters across sweeps (monitoring).
    pub total_promoted: std::sync::atomic::AtomicUsize,
    pub total_healed: std::sync::atomic::AtomicUsize,
    pub total_rejoined: std::sync::atomic::AtomicUsize,
    pub total_checkpointed: std::sync::atomic::AtomicUsize,
}

impl AvailabilityManager {
    pub fn new(cluster: Arc<DbCluster>) -> AvailabilityManager {
        AvailabilityManager {
            cluster,
            sweeps: AtomicUsize::new(0),
            total_promoted: std::sync::atomic::AtomicUsize::new(0),
            total_healed: std::sync::atomic::AtomicUsize::new(0),
            total_rejoined: std::sync::atomic::AtomicUsize::new(0),
            total_checkpointed: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// One sweep: count dead nodes, promote backups whose primary is dead,
    /// re-seed stale replicas where both sides are alive again, and drive
    /// rejoining nodes through catch-up to the serving hand-off.
    pub fn sweep(&self) -> Result<SweepReport> {
        let obs = self.cluster.obs().clone();
        let t_sweep = obs.start();
        let mut r = SweepReport::default();
        let n = self.cluster.num_nodes() as u32;
        for i in 0..n {
            match self.cluster.node(i).map(|nd| nd.state()) {
                Some(NodeState::Dead) => r.dead_nodes += 1,
                Some(NodeState::Rejoining) => r.rejoining += 1,
                _ => {}
            }
        }
        r.promoted = self.cluster.promote_dead_primaries();
        r.healed = self.cluster.heal()?;
        for i in 0..n {
            let rejoining = self
                .cluster
                .node(i)
                .map_or(false, |nd| nd.state() == NodeState::Rejoining);
            if !rejoining {
                continue;
            }
            let t_rejoin = obs.start();
            for _ in 0..CATCHUP_ROUNDS {
                r.shipped_ops += self.cluster.rejoin_catchup_round(i)?;
            }
            match self.cluster.rejoin_final_cut(i) {
                Ok((shipped, reseeded)) => {
                    r.shipped_ops += shipped;
                    r.reseeded_parts += reseeded;
                    r.rejoined += 1;
                    obs.rec_since(Hist::Rejoin, t_rejoin);
                    obs.inc(Counter::Rejoins);
                }
                // e.g. the peer hosting the serving replica is down too:
                // leave the node rejoining, a later sweep retries
                Err(e) => log::warn!("rejoin of node {i} incomplete: {e}"),
            }
        }
        // Automatic checkpoint cadence: every `checkpoint_every_sweeps`
        // sweeps, cut incremental per-partition checkpoints on every
        // serving node. Incremental means a quiet partition skips (its
        // on-disk cut already matches `(version, epoch)`), so an
        // on-cadence sweep over an idle cluster is still cheap.
        let sweep_no = self.sweeps.fetch_add(1, Ordering::Relaxed) + 1;
        let cadence = self
            .cluster
            .durability()
            .map_or(0, |d| d.checkpoint_every_sweeps);
        if cadence > 0 && sweep_no % cadence == 0 {
            for i in 0..n {
                let alive = self.cluster.node(i).map_or(false, |nd| nd.is_alive());
                if !alive {
                    continue; // dead/rejoining state is not a valid cut
                }
                match checkpoint::checkpoint_node(&self.cluster, i) {
                    Ok(cr) => r.checkpointed += cr.written,
                    Err(e) => log::warn!("cadence checkpoint of node {i} failed: {e}"),
                }
            }
        }
        self.total_promoted.fetch_add(r.promoted, std::sync::atomic::Ordering::Relaxed);
        self.total_healed.fetch_add(r.healed, std::sync::atomic::Ordering::Relaxed);
        self.total_rejoined.fetch_add(r.rejoined, std::sync::atomic::Ordering::Relaxed);
        self.total_checkpointed.fetch_add(r.checkpointed, std::sync::atomic::Ordering::Relaxed);
        obs.rec_since(Hist::Sweep, t_sweep);
        obs.inc(Counter::SweepRuns);
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
    use crate::storage::stats::AccessKind;
    use crate::storage::value::Value;

    fn cluster() -> Arc<DbCluster> {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        c
    }

    fn durable_cluster(tag: &str) -> (Arc<DbCluster>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "schaladb-repl-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DbCluster::start(
            ClusterConfig::builder()
                .durability(DurabilityConfig::new(dir.clone(), 4))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        (c, dir)
    }

    #[test]
    fn healthy_sweep_is_a_noop() {
        let c = cluster();
        let am = AvailabilityManager::new(c);
        let r = am.sweep().unwrap();
        assert_eq!(r, SweepReport::default());
    }

    #[test]
    fn sweep_detects_dead_primary_and_promotes() {
        let c = cluster();
        let am = AvailabilityManager::new(c.clone());
        let epoch0 = c.cluster_epoch();
        c.kill_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.dead_nodes, 1);
        assert!(r.promoted > 0, "node 0 hosted primaries that must be promoted");
        assert_eq!(r.rejoined, 0);
        assert!(c.cluster_epoch() > epoch0, "promotion must open a new epoch");

        // data fully available during the outage
        let rs = c.query("SELECT COUNT(*) FROM t").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(20));
        // and writable (writes land on promoted primaries, with the backup
        // side degraded)
        c.execute("UPDATE t SET v = 99.0 WHERE id = 3").unwrap();
    }

    #[test]
    fn kill_promote_revive_heal_cycle() {
        let c = cluster();
        let am = AvailabilityManager::new(c.clone());

        c.kill_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.promoted > 0);
        c.execute("UPDATE t SET v = 99.0 WHERE id = 3").unwrap();

        // revive (memory intact): heal re-seeds the stale replicas
        c.revive_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.healed > 0, "stale replicas on revived node must be re-seeded");

        // after heal, a second failure of the *other* node is survivable
        c.kill_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.promoted > 0);
        let rs = c.query("SELECT v FROM t WHERE id = 3").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Float(99.0));
    }

    #[test]
    fn sweep_drives_restart_rejoin_handoff() {
        let (c, dir) = durable_cluster("rejoin");
        let am = AvailabilityManager::new(c.clone());
        let fp_before_kill = c.fingerprint().unwrap();

        c.kill_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.promoted > 0);
        // writes continue against the survivor while node 1 is down
        c.execute("UPDATE t SET v = -1.0 WHERE id = 5").unwrap();
        c.execute("INSERT INTO t (id, v) VALUES (100, 0.5)").unwrap();

        // process restart: wiped memory, local recovery, rejoin state
        let start = c.restart_node(1).unwrap();
        assert!(start.partitions > 0);
        let sr = am.sweep().unwrap();
        assert_eq!(sr.rejoining, 1);
        assert_eq!(sr.rejoined, 1, "one sweep must complete the hand-off");
        assert!(c.node(1).unwrap().is_alive(), "node serves again after the cut");
        assert!(
            sr.shipped_ops > 0 || sr.reseeded_parts > 0,
            "catch-up must have moved data: {sr:?}"
        );
        assert_eq!(
            am.total_rejoined.load(std::sync::atomic::Ordering::Relaxed),
            1
        );

        // the rejoined node is a faithful replica: kill the survivor and
        // serve everything from the rejoined one
        c.kill_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert!(r.promoted > 0);
        let rs = c.query("SELECT COUNT(*) FROM t").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(21));
        let rs = c.query("SELECT v FROM t WHERE id = 5").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Float(-1.0));
        assert_ne!(c.fingerprint().unwrap(), fp_before_kill, "writes visible");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejoin_without_peer_stays_pending() {
        let (c, dir) = durable_cluster("pending");
        let am = AvailabilityManager::new(c.clone());
        c.kill_node(0).unwrap();
        am.sweep().unwrap();
        c.kill_node(1).unwrap();
        // node 0 restarts while node 1 (now sole serving replica) is dead:
        // the hand-off cannot complete, the sweep must not flip it alive
        c.restart_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.rejoined, 0);
        assert_eq!(r.rejoining, 1);
        assert!(!c.node(0).unwrap().is_alive());
        // once the peer revives, the next sweep completes the rejoin
        c.revive_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.rejoined, 1);
        assert!(c.node(0).unwrap().is_alive());
        let rs = c.query("SELECT COUNT(*) FROM t").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(20));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `replication: false` means some partitions have exactly one
    /// replica. A restart of their node must still complete the rejoin:
    /// there is no peer to catch up from, so the local checkpoint + WAL
    /// recovery is authoritative and the sweep flips the node back alive.
    ///
    /// `group_commit: 1` (per-commit flush) on purpose: a crash loses the
    /// buffered group-commit tail, and a sole-replica partition has no
    /// peer to recover it from — full recovery is only guaranteed at
    /// window size 1 (see `restart_recovers_only_the_flushed_prefix` for
    /// the loss-window semantics at larger windows).
    #[test]
    fn sole_replica_rejoin_completes_from_local_recovery() {
        use crate::storage::checkpoint::checkpoint_node;
        let dir = std::env::temp_dir().join(format!(
            "schaladb-repl-sole-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DbCluster::start(
            ClusterConfig::builder()
                .replication(false)
                .durability(DurabilityConfig::new(dir.clone(), 1))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        checkpoint_node(&c, 1).unwrap();
        for i in 20..30 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        let before = c.table_rows("t").unwrap();
        assert_eq!(before, 30);

        let am = AvailabilityManager::new(c.clone());
        c.kill_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.promoted, 0, "nothing to promote without backups");
        assert!(c.table_rows("t").unwrap() < before, "sole replicas are down");

        let start = c.restart_node(1).unwrap();
        assert!(start.from_checkpoint > 0);
        let r = am.sweep().unwrap();
        assert_eq!(r.rejoined, 1, "sole-replica node must not wedge in Rejoining");
        assert!(c.node(1).unwrap().is_alive());
        assert_eq!(
            c.table_rows("t").unwrap(),
            before,
            "checkpoint + WAL tail must restore every sole replica"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A simulated process crash must lose the buffered group-commit tail
    /// (up to `group_commit - 1` commits per node) — the restart used to
    /// flush the dying node's buffers to disk first, making recovery
    /// tests verify durability the code does not provide. With no peer
    /// (replication off) and no checkpoint, the restart recovers exactly
    /// the flushed prefix: consistent, but strictly short of the full
    /// committed stream.
    #[test]
    fn restart_recovers_only_the_flushed_prefix() {
        let dir = std::env::temp_dir().join(format!(
            "schaladb-repl-lossy-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let group_commit = 8;
        let c = DbCluster::start(
            ClusterConfig::builder()
                .replication(false)
                .durability(DurabilityConfig::new(dir.clone(), group_commit))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        // node 1 hosts partitions 1 and 3 → 15 of these 30 single-row
        // commits land on it; 15 % 8 != 0, so its last sub-group is
        // buffered and must die with the crash
        for i in 0..30 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        let before = c.table_rows("t").unwrap();
        assert_eq!(before, 30);

        let am = AvailabilityManager::new(c.clone());
        c.kill_node(1).unwrap();
        c.restart_node(1).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.rejoined, 1);
        let after = c.table_rows("t").unwrap();
        assert!(
            after < before,
            "the unflushed group-commit tail must be lost in a crash, got {after}"
        );
        assert!(
            after >= before - (group_commit - 1),
            "loss must be bounded by the group-commit window: {after}"
        );
        // the recovered prefix is a live, consistent state: new writes work
        c.execute("INSERT INTO t (id, v) VALUES (100, 1.0)").unwrap();
        assert_eq!(c.table_rows("t").unwrap(), after + 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression for the rejoin hand-off race: a write that built its
    /// lock set while a node was `Rejoining` but acquired its latches only
    /// after the final cut flipped it `Alive` used to apply to the primary
    /// alone while still logging to the rejoined node's WAL — the fresh
    /// replica silently missed the write. The mirror set is now
    /// re-validated under the held latches, so writes racing the hand-off
    /// land on both replicas: after the writer quiesces, the two nodes'
    /// stores must be identical with **no** extra heal sweep.
    #[test]
    fn writes_racing_the_rejoin_handoff_reach_both_replicas() {
        for round in 0..4 {
            let (c, dir) = durable_cluster(&format!("handoff-race-{round}"));
            let am = AvailabilityManager::new(c.clone());
            c.kill_node(1).unwrap();
            am.sweep().unwrap();
            c.execute("UPDATE t SET v = -2.0 WHERE id = 7").unwrap();
            c.restart_node(1).unwrap();

            let writer = {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..300i64 {
                        let id = i % 20;
                        loop {
                            match c.execute(&format!("UPDATE t SET v = {i}.0 WHERE id = {id}")) {
                                Ok(_) => break,
                                Err(crate::Error::Unavailable(_)) => {
                                    std::thread::sleep(std::time::Duration::from_micros(50));
                                }
                                Err(e) => panic!("writer failed mid-handoff: {e}"),
                            }
                        }
                    }
                })
            };
            // drive the rejoin while the writer hammers the same partitions
            let mut rejoined = false;
            for _ in 0..200 {
                if am.sweep().unwrap().rejoined > 0 {
                    rejoined = true;
                    break;
                }
            }
            writer.join().unwrap();
            assert!(rejoined, "node 1 must rejoin under write load");

            // byte-equal replicas, without any post-hoc heal sweep
            let n0 = c.node(0).unwrap().clone();
            let n1 = c.node(1).unwrap().clone();
            for (table, pidx) in n1.hosted_keys() {
                let a = n0.partition_even_if_dead(&table, pidx).unwrap();
                let b = n1.partition_even_if_dead(&table, pidx).unwrap();
                let (ag, bg) = (a.read().unwrap(), b.read().unwrap());
                assert_eq!(
                    ag.version, bg.version,
                    "replica LSNs diverged on {table}[{pidx}] across the hand-off"
                );
                assert_eq!(
                    ag.snapshot_slotted(),
                    bg.snapshot_slotted(),
                    "replica rows diverged on {table}[{pidx}] across the hand-off"
                );
            }
            // and the rejoined replica keeps accepting mirrored redo (the
            // divergence symptom was a slot-occupied panic right here)
            c.execute("INSERT INTO t (id, v) VALUES (500, 5.0)").unwrap();
            c.execute("DELETE FROM t WHERE id = 3").unwrap();
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// The same hand-off race with the claims on the **optimistic** path:
    /// OCC's commit section derives its mirror set, WAL targets, and
    /// epoch from the liveness observed under the held latches (exactly
    /// like the 2PL fast path), so writes racing the rejoin flip must
    /// land on both replicas — and the run must actually exercise OCC
    /// commits, not silently fall back.
    #[test]
    fn occ_writes_racing_the_rejoin_handoff_reach_both_replicas() {
        let dir = std::env::temp_dir().join(format!(
            "schaladb-repl-occ-handoff-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DbCluster::start(
            ClusterConfig::builder()
                .durability(DurabilityConfig::new(dir.clone(), 4))
                .concurrency(ConcurrencyMode::Occ)
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        let am = AvailabilityManager::new(c.clone());
        c.kill_node(1).unwrap();
        am.sweep().unwrap();
        c.execute("UPDATE t SET v = -2.0 WHERE id = 7").unwrap();
        c.restart_node(1).unwrap();

        let writer = {
            let c = c.clone();
            std::thread::spawn(move || {
                // prepared PK point updates: the shape the OCC path takes
                let upd = c.prepare("UPDATE t SET v = ? WHERE id = ?").unwrap();
                for i in 0..300i64 {
                    let id = i % 20;
                    loop {
                        match c.exec_prepared(
                            0,
                            AccessKind::UpdateToRunning,
                            &upd,
                            &[Value::Float(i as f64), Value::Int(id)],
                        ) {
                            Ok(_) => break,
                            Err(crate::Error::Unavailable(_)) => {
                                std::thread::sleep(std::time::Duration::from_micros(50));
                            }
                            Err(e) => panic!("occ writer failed mid-handoff: {e}"),
                        }
                    }
                }
            })
        };
        let mut rejoined = false;
        for _ in 0..200 {
            if am.sweep().unwrap().rejoined > 0 {
                rejoined = true;
                break;
            }
        }
        writer.join().unwrap();
        assert!(rejoined, "node 1 must rejoin under OCC write load");

        let rc = c.route_counts();
        assert!(
            rc.occ_dml > 0,
            "the run must commit through the OCC path, not fall back everywhere"
        );
        let n0 = c.node(0).unwrap().clone();
        let n1 = c.node(1).unwrap().clone();
        for (table, pidx) in n1.hosted_keys() {
            let a = n0.partition_even_if_dead(&table, pidx).unwrap();
            let b = n1.partition_even_if_dead(&table, pidx).unwrap();
            let (ag, bg) = (a.read().unwrap(), b.read().unwrap());
            assert_eq!(
                ag.version, bg.version,
                "replica LSNs diverged on {table}[{pidx}] across the OCC hand-off"
            );
            assert_eq!(
                ag.snapshot_slotted(),
                bg.snapshot_slotted(),
                "replica rows diverged on {table}[{pidx}] across the OCC hand-off"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The automatic checkpoint cadence: every Nth sweep cuts incremental
    /// per-partition checkpoints on every serving node; off-cadence sweeps
    /// cut nothing, and an on-cadence sweep over an unchanged cluster
    /// skips every partition (the incremental rule).
    #[test]
    fn sweep_cuts_checkpoints_on_cadence() {
        let dir = std::env::temp_dir().join(format!(
            "schaladb-repl-cadence-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = DbCluster::start(
            ClusterConfig::builder()
                .durability(DurabilityConfig::new(dir.clone(), 4).with_checkpoint_cadence(2))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE t (id INT NOT NULL, v FLOAT) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!("INSERT INTO t (id, v) VALUES ({i}, {i}.5)")).unwrap();
        }
        let am = AvailabilityManager::new(c.clone());
        // sweep 1: off-cadence, no cut
        assert_eq!(am.sweep().unwrap().checkpointed, 0);
        // sweep 2: on-cadence, every hosted partition replica gets a cut
        let r = am.sweep().unwrap();
        assert!(r.checkpointed > 0, "on-cadence sweep must cut checkpoints");
        let first = r.checkpointed;
        // sweeps 3+4 with no writes: the on-cadence cut skips everything
        assert_eq!(am.sweep().unwrap().checkpointed, 0);
        assert_eq!(
            am.sweep().unwrap().checkpointed,
            0,
            "unchanged partitions must be skipped by the incremental rule"
        );
        // one write dirties one partition (on both of its replicas)
        c.execute("UPDATE t SET v = -1.0 WHERE id = 3").unwrap();
        am.sweep().unwrap();
        let r = am.sweep().unwrap();
        assert!(
            r.checkpointed >= 1 && r.checkpointed < first,
            "only the dirtied partition's replicas re-cut, got {}",
            r.checkpointed
        );
        assert!(
            am.total_checkpointed.load(std::sync::atomic::Ordering::Relaxed)
                >= first + r.checkpointed
        );
        // the cadence-driven cut is a real, loadable checkpoint
        let node_dir = dir.join("node0");
        let mut found = 0;
        for e in std::fs::read_dir(&node_dir).unwrap() {
            let p = e.unwrap().path();
            if p.extension().map_or(false, |x| x == "ckpt") {
                crate::storage::checkpoint::load_partition_checkpoint(&p).unwrap();
                found += 1;
            }
        }
        assert!(found > 0, "node0 must hold cadence-cut checkpoint files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cumulative_counters() {
        let c = cluster();
        let am = AvailabilityManager::new(c.clone());
        c.kill_node(1).unwrap();
        am.sweep().unwrap();
        // a write during the outage makes node 1's replicas stale, so the
        // post-revival sweep has something to heal
        c.execute("UPDATE t SET v = 1.0").unwrap();
        c.revive_node(1).unwrap();
        am.sweep().unwrap();
        assert!(am.total_promoted.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert!(am.total_healed.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
}
