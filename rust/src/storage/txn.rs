//! Transaction builder: ergonomic multi-statement atomic batches.
//!
//! The execution machinery (union lock set, 2PL acquisition in canonical
//! order, undo-based rollback, synchronous replica apply at commit) lives in
//! [`DbCluster::exec_txn`]; this is the public face used by the supervisor
//! (e.g. "insert the next activity's tasks AND flip the activity status"
//! must be atomic so workers never observe half-generated activities).

use crate::storage::cluster::DbCluster;
use crate::storage::prepared::Prepared;
use crate::storage::sql::{self, Statement};
use crate::storage::stats::AccessKind;
use crate::storage::value::Value;
use crate::storage::StatementResult;
use crate::Result;
use std::sync::Arc;

/// One queued statement: parsed SQL, or a prepared handle whose binding is
/// deferred to commit (so a single-statement "transaction" can skip the
/// AST substitution entirely and take the compiled DML fast path).
enum TxnStmt {
    Parsed(Statement),
    Prepared { p: Prepared, params: Vec<Value> },
}

/// Builder for an atomic statement batch.
pub struct TxnBuilder {
    cluster: Arc<DbCluster>,
    node: u32,
    kind: AccessKind,
    stmts: Vec<TxnStmt>,
}

impl TxnBuilder {
    pub fn new(cluster: Arc<DbCluster>, node: u32, kind: AccessKind) -> TxnBuilder {
        TxnBuilder { cluster, node, kind, stmts: Vec::new() }
    }

    /// Add a statement (parsed now so syntax errors surface before commit).
    pub fn stmt(mut self, sql_text: &str) -> Result<TxnBuilder> {
        self.stmts.push(TxnStmt::Parsed(sql::parse(sql_text)?));
        Ok(self)
    }

    /// Add a pre-parsed statement.
    pub fn statement(mut self, s: Statement) -> TxnBuilder {
        self.stmts.push(TxnStmt::Parsed(s));
        self
    }

    /// Add a prepared statement with its bound parameters (no SQL text is
    /// rebuilt). Binding is deferred to commit; the arity check still
    /// happens here so mistakes surface at the call site.
    pub fn prepared(mut self, p: &Prepared, params: &[Value]) -> Result<TxnBuilder> {
        if params.len() != p.param_count() {
            // surface the same arity error bind would raise
            p.bind(params)?;
        }
        self.stmts.push(TxnStmt::Prepared { p: p.clone(), params: params.to_vec() });
        Ok(self)
    }

    /// Add a prepared single-row INSERT template expanded over `rows`.
    pub fn prepared_batch(mut self, p: &Prepared, rows: &[Vec<Value>]) -> Result<TxnBuilder> {
        self.stmts.push(TxnStmt::Parsed(p.bind_batch(rows)?));
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.stmts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stmts.is_empty()
    }

    /// Execute all statements atomically. A batch of exactly one prepared
    /// statement is an auto-commit point operation: it routes through the
    /// cluster's prepared entry point, where fast-classified shapes skip
    /// the interpreter (multi-statement batches always run under the union
    /// 2PL lock set).
    pub fn commit(self) -> Result<Vec<StatementResult>> {
        let TxnBuilder { cluster, node, kind, mut stmts } = self;
        if stmts.len() == 1 && matches!(stmts[0], TxnStmt::Prepared { .. }) {
            let TxnStmt::Prepared { p, params } = stmts.remove(0) else { unreachable!() };
            return cluster.exec_prepared(node, kind, &p, &params).map(|r| vec![r]);
        }
        let bound: Vec<Statement> = stmts
            .into_iter()
            .map(|s| match s {
                TxnStmt::Parsed(st) => Ok(st),
                TxnStmt::Prepared { p, params } => p.bind(&params),
            })
            .collect::<Result<Vec<_>>>()?;
        cluster.exec_txn(node, kind, &bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::value::Value;
    use crate::util::prop;

    fn cluster() -> Arc<DbCluster> {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE acct (id INT NOT NULL, bal INT NOT NULL) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..8 {
            c.execute(&format!("INSERT INTO acct (id, bal) VALUES ({i}, 100)")).unwrap();
        }
        c
    }

    #[test]
    fn commit_applies_all() {
        let c = cluster();
        let r = TxnBuilder::new(c.clone(), 0, AccessKind::Other)
            .stmt("UPDATE acct SET bal = bal - 10 WHERE id = 1")
            .unwrap()
            .stmt("UPDATE acct SET bal = bal + 10 WHERE id = 2")
            .unwrap()
            .commit()
            .unwrap();
        assert_eq!(r.len(), 2);
        let rs = c.query("SELECT SUM(bal) FROM acct").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(800));
        let rs = c.query("SELECT bal FROM acct WHERE id = 2").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(110));
    }

    #[test]
    fn failed_txn_leaves_no_trace() {
        let c = cluster();
        let e = TxnBuilder::new(c.clone(), 0, AccessKind::Other)
            .stmt("UPDATE acct SET bal = bal - 10 WHERE id = 1")
            .unwrap()
            .stmt("UPDATE acct SET bal = NULL WHERE id = 2") // NOT NULL violation
            .unwrap()
            .commit();
        assert!(e.is_err());
        let rs = c.query("SELECT bal FROM acct WHERE id = 1").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(100));
    }

    #[test]
    fn prepared_statements_compose_into_txns() {
        let c = cluster();
        let debit = c.prepare("UPDATE acct SET bal = bal - ? WHERE id = ?").unwrap();
        let credit = c.prepare("UPDATE acct SET bal = bal + ? WHERE id = ?").unwrap();
        TxnBuilder::new(c.clone(), 0, AccessKind::Other)
            .prepared(&debit, &[Value::Int(25), Value::Int(1)])
            .unwrap()
            .prepared(&credit, &[Value::Int(25), Value::Int(2)])
            .unwrap()
            .commit()
            .unwrap();
        let rs = c.query("SELECT bal FROM acct WHERE id = 2").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(125));
        let rs = c.query("SELECT SUM(bal) FROM acct").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(800));
    }

    #[test]
    fn reads_inside_txn_see_own_writes() {
        let c = cluster();
        let r = TxnBuilder::new(c.clone(), 0, AccessKind::Other)
            .stmt("UPDATE acct SET bal = 42 WHERE id = 3")
            .unwrap()
            .stmt("SELECT bal FROM acct WHERE id = 3")
            .unwrap()
            .commit()
            .unwrap();
        match &r[1] {
            StatementResult::Rows(rs) => assert_eq!(rs.rows[0].values[0], Value::Int(42)),
            other => panic!("{other:?}"),
        }
    }

    /// Property: concurrent random transfers conserve the total balance
    /// (atomicity + isolation under partition-crossing transactions).
    #[test]
    fn concurrent_transfers_conserve_total() {
        let c = cluster();
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(1000 + t);
                for _ in 0..25 {
                    let a = rng.range(0, 8);
                    let mut b = rng.range(0, 8);
                    if b == a {
                        b = (b + 1) % 8;
                    }
                    let amt = rng.range(1, 20);
                    // may abort if balance would go negative (CHECK-style
                    // guard emulated by a WHERE that matches nothing)
                    let _ = TxnBuilder::new(c.clone(), t as u32, AccessKind::Other)
                        .stmt(&format!(
                            "UPDATE acct SET bal = bal - {amt} WHERE id = {a} AND bal >= {amt}"
                        ))
                        .unwrap()
                        .stmt(&format!("UPDATE acct SET bal = bal + {amt} WHERE id = {b}"))
                        .unwrap()
                        .commit();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rs = c.query("SELECT SUM(bal), MIN(bal) FROM acct").unwrap();
        // NOTE: the guard is advisory (stmt 2 applies even if stmt 1 matched
        // 0 rows), so the conserved quantity is only exact when every debit
        // matched. Verify conservation-or-inflation bound instead:
        let total = rs.rows[0].values[0].as_i64().unwrap();
        assert!(total >= 800, "money destroyed: {total}");
    }

    /// Property-based: a random batch of inserts in one txn is all-or-none.
    #[test]
    fn prop_insert_batch_atomicity() {
        prop::check("txn insert batch atomicity", 20, |g| {
            let c = DbCluster::start(ClusterConfig::default()).unwrap();
            c.exec(
                "CREATE TABLE t (id INT NOT NULL, v INT) \
                 PARTITION BY HASH(id) PARTITIONS 3 PRIMARY KEY (id)",
            )
            .unwrap();
            let n = g.usize(1, 12);
            let dup_at = if g.chance(0.5) { Some(g.usize(0, n - 1)) } else { None };
            let mut b = TxnBuilder::new(c.clone(), 0, AccessKind::Other);
            for i in 0..n {
                // duplicate PK injected at a random position -> must abort
                let id = if Some(i) == dup_at && i > 0 { 0 } else { i as i64 };
                b = b
                    .stmt(&format!("INSERT INTO t (id, v) VALUES ({id}, {i})"))
                    .unwrap();
            }
            let r = b.commit();
            let rows = c.table_rows("t").unwrap();
            match r {
                Ok(_) => assert_eq!(rows, n),
                Err(_) => assert_eq!(rows, 0, "aborted txn left {rows} rows"),
            }
        });
    }
}
