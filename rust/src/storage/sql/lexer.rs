//! SQL tokenizer.

use crate::{Error, Result};

/// A lexical token. Keywords are not distinguished here — the parser
/// matches identifiers case-insensitively against keyword names, which keeps
/// the lexer small and lets column names shadow nothing.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Int(i64),
    Float(f64),
    Str(String),
    /// Symbols: ( ) , . * = != <> < <= > >= + - / % ? ;
    Sym(&'static str),
    Eof,
}

impl Tok {
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("identifier '{s}'"),
            Tok::Int(i) => format!("integer {i}"),
            Tok::Float(f) => format!("float {f}"),
            Tok::Str(s) => format!("string '{s}'"),
            Tok::Sym(s) => format!("'{s}'"),
            Tok::Eof => "end of input".into(),
        }
    }
}

/// Tokenize a full statement. Positions are tracked for error messages.
pub fn lex(input: &str) -> Result<Vec<Tok>> {
    let b = input.as_bytes();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i] as char;
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            '-' if i + 1 < b.len() && b[i + 1] == b'-' => {
                // line comment
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            '\'' => {
                // string literal with '' escaping
                let mut s = String::new();
                i += 1;
                loop {
                    if i >= b.len() {
                        return Err(Error::Parse("unterminated string literal".into()));
                    }
                    if b[i] == b'\'' {
                        if i + 1 < b.len() && b[i + 1] == b'\'' {
                            s.push('\'');
                            i += 2;
                        } else {
                            i += 1;
                            break;
                        }
                    } else {
                        // handle multi-byte UTF-8 safely by slicing chars
                        let ch_len = utf8_len(b[i]);
                        s.push_str(&input[i..i + ch_len]);
                        i += ch_len;
                    }
                }
                out.push(Tok::Str(s));
            }
            '0'..='9' => {
                let start = i;
                while i < b.len() && (b[i] as char).is_ascii_digit() {
                    i += 1;
                }
                let mut is_float = false;
                if i < b.len() && b[i] == b'.' && i + 1 < b.len() && (b[i + 1] as char).is_ascii_digit()
                {
                    is_float = true;
                    i += 1;
                    while i < b.len() && (b[i] as char).is_ascii_digit() {
                        i += 1;
                    }
                }
                if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
                        j += 1;
                    }
                    if j < b.len() && (b[j] as char).is_ascii_digit() {
                        is_float = true;
                        i = j;
                        while i < b.len() && (b[i] as char).is_ascii_digit() {
                            i += 1;
                        }
                    }
                }
                let text = &input[start..i];
                if is_float {
                    out.push(Tok::Float(text.parse().map_err(|e| {
                        Error::Parse(format!("bad float '{text}': {e}"))
                    })?));
                } else {
                    out.push(Tok::Int(text.parse().map_err(|e| {
                        Error::Parse(format!("bad integer '{text}': {e}"))
                    })?));
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() {
                    let c = b[i] as char;
                    if c.is_ascii_alphanumeric() || c == '_' {
                        i += 1;
                    } else {
                        break;
                    }
                }
                out.push(Tok::Ident(input[start..i].to_string()));
            }
            '!' if i + 1 < b.len() && b[i + 1] == b'=' => {
                out.push(Tok::Sym("!="));
                i += 2;
            }
            '<' => {
                if i + 1 < b.len() && b[i + 1] == b'=' {
                    out.push(Tok::Sym("<="));
                    i += 2;
                } else if i + 1 < b.len() && b[i + 1] == b'>' {
                    out.push(Tok::Sym("!="));
                    i += 2;
                } else {
                    out.push(Tok::Sym("<"));
                    i += 1;
                }
            }
            '>' => {
                if i + 1 < b.len() && b[i + 1] == b'=' {
                    out.push(Tok::Sym(">="));
                    i += 2;
                } else {
                    out.push(Tok::Sym(">"));
                    i += 1;
                }
            }
            '(' | ')' | ',' | '.' | '*' | '=' | '+' | '-' | '/' | '%' | ';' | '?' => {
                out.push(Tok::Sym(match c {
                    '(' => "(",
                    ')' => ")",
                    ',' => ",",
                    '.' => ".",
                    '*' => "*",
                    '=' => "=",
                    '+' => "+",
                    '-' => "-",
                    '/' => "/",
                    '%' => "%",
                    ';' => ";",
                    '?' => "?",
                    _ => unreachable!(),
                }));
                i += 1;
            }
            other => {
                return Err(Error::Parse(format!(
                    "unexpected character '{other}' at byte {i}"
                )))
            }
        }
    }
    out.push(Tok::Eof);
    Ok(out)
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lex_basic_statement() {
        let toks = lex("SELECT a, b FROM t WHERE x >= 1.5 AND s = 'it''s'").unwrap();
        assert!(toks.contains(&Tok::Ident("SELECT".into())));
        assert!(toks.contains(&Tok::Sym(">=")));
        assert!(toks.contains(&Tok::Float(1.5)));
        assert!(toks.contains(&Tok::Str("it's".into())));
        assert_eq!(*toks.last().unwrap(), Tok::Eof);
    }

    #[test]
    fn lex_numbers() {
        assert_eq!(lex("42").unwrap()[0], Tok::Int(42));
        assert_eq!(lex("4.25").unwrap()[0], Tok::Float(4.25));
        assert_eq!(lex("1e3").unwrap()[0], Tok::Float(1000.0));
        assert_eq!(lex("2.5e-2").unwrap()[0], Tok::Float(0.025));
        // '4.' is Int then Sym(".") — qualified-name dots must survive
        let t = lex("t.col").unwrap();
        assert_eq!(t[0], Tok::Ident("t".into()));
        assert_eq!(t[1], Tok::Sym("."));
        assert_eq!(t[2], Tok::Ident("col".into()));
    }

    #[test]
    fn lex_comments_and_neq_forms() {
        let t = lex("a <> b -- comment\n != c").unwrap();
        assert_eq!(t.iter().filter(|x| **x == Tok::Sym("!=")).count(), 2);
    }

    #[test]
    fn lex_rejects_garbage_and_unterminated() {
        assert!(lex("select #").is_err());
        assert!(lex("'abc").is_err());
    }

    #[test]
    fn lex_parameter_placeholders() {
        let t = lex("SELECT a FROM t WHERE b = ? AND c = ?").unwrap();
        assert_eq!(t.iter().filter(|x| **x == Tok::Sym("?")).count(), 2);
    }

    #[test]
    fn lex_utf8_in_strings() {
        let t = lex("'café ✓'").unwrap();
        assert_eq!(t[0], Tok::Str("café ✓".into()));
    }
}
