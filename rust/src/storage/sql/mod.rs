//! SQL dialect for the engine.
//!
//! A deliberately small but real subset, enough for everything the paper's
//! workloads need: the scheduling point queries (`select/update ... where
//! worker_id = i`), the Table-2 steering analytics (multi-join, GROUP BY,
//! HAVING, ORDER BY, subquery-free aggregates), and DDL for the d-Chiron
//! database:
//!
//! ```sql
//! CREATE TABLE t (a INT NOT NULL, b FLOAT, c TEXT)
//!   [PARTITION BY HASH(a) PARTITIONS n] [PRIMARY KEY (a)] [INDEX (c)]
//! INSERT INTO t (a, b) VALUES (1, 2.0), (3, 4.0)
//! SELECT x.a, COUNT(*) AS n FROM t x JOIN u ON x.a = u.a
//!   WHERE b > 1 AND c LIKE 'RE%' GROUP BY x.a HAVING n > 2
//!   ORDER BY n DESC LIMIT 5
//! UPDATE t SET b = b + 1 WHERE a IN (1, 2) [ORDER BY a] [LIMIT k] [RETURNING a, b]
//! DELETE FROM t WHERE ...
//! ```
//!
//! `UPDATE ... LIMIT k RETURNING` is the atomic task-dequeue primitive
//! (equivalent to `SELECT ... FOR UPDATE` + `UPDATE` in MySQL Cluster): a
//! worker claims `k` READY tasks and learns which ones in a single
//! partition-local transaction.

pub mod ast;
pub mod exec;
pub mod expr;
pub mod lexer;
pub mod parser;

pub use ast::*;
pub use parser::{parse_prepared, parse_statement};

use crate::Result;

/// Parse exactly one statement from `sql`.
pub fn parse(sql: &str) -> Result<Statement> {
    parse_statement(sql)
}

/// Escape a string for embedding inside a single-quoted SQL literal: the
/// dialect's only escape is quote doubling (`''`), so this is the complete
/// rule. Prefer `?` parameters on anything resembling a hot path — this
/// helper exists for the few places that must render literal SQL text
/// (checkpoint dumps, ad-hoc CLI statements).
pub fn escape_sql_str(s: &str) -> String {
    s.replace('\'', "''")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_sql_str_round_trips_through_the_lexer() {
        for raw in ["it's", "O'Brien said ''hi''", "no quotes", "'", "''"] {
            let sql = format!("SELECT * FROM t WHERE s = '{}'", escape_sql_str(raw));
            let stmt = parse(&sql).unwrap_or_else(|e| panic!("failed on {raw:?}: {e}"));
            let Statement::Select(s) = stmt else { panic!("not a select") };
            match s.where_.unwrap() {
                Expr::Binary(_, _, rhs) => {
                    assert_eq!(
                        *rhs,
                        Expr::Lit(crate::storage::value::Value::str(raw)),
                        "round-trip mangled {raw:?}"
                    );
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn unescaped_quote_is_rejected_not_misparsed() {
        // the historical hazard: a raw quote inside an interpolated value
        assert!(parse("UPDATE t SET stdout = 'it's' WHERE id = 1").is_err());
    }

    #[test]
    fn parse_roundtrip_smoke() {
        for sql in [
            "SELECT * FROM workqueue",
            "select taskid, status from workqueue where workerid = 3 and status = 'READY' order by taskid limit 16",
            "INSERT INTO t (a,b) VALUES (1, 'x'), (2, 'y')",
            "UPDATE t SET s = 'RUNNING', st = NOW() WHERE wid = 2 AND s = 'READY' ORDER BY id LIMIT 4 RETURNING id, cmd",
            "DELETE FROM t WHERE a BETWEEN 1 AND 5",
            "CREATE TABLE t (a INT NOT NULL, b FLOAT, c TEXT) PARTITION BY HASH(a) PARTITIONS 8 PRIMARY KEY (a) INDEX (c)",
            "SELECT w.node, COUNT(*) AS n, AVG(t.dur) FROM tasks t JOIN workers w ON t.wid = w.id WHERE t.endt >= NOW() - 60 GROUP BY w.node HAVING COUNT(*) > 1 ORDER BY n DESC, w.node ASC LIMIT 10",
        ] {
            parse(sql).unwrap_or_else(|e| panic!("failed on {sql}: {e}"));
        }
    }
}
