//! Recursive-descent SQL parser with precedence climbing for expressions.

use super::ast::*;
use super::lexer::{lex, Tok};
use crate::storage::value::{ColumnType, Value};
use crate::{Error, Result};

/// Parse exactly one statement (a trailing `;` is tolerated).
pub fn parse_statement(sql: &str) -> Result<Statement> {
    parse_prepared(sql).map(|(stmt, _)| stmt)
}

/// Parse one statement that may contain `?` placeholders; returns the
/// statement plus the number of parameters (ordinals assigned left-to-right).
pub fn parse_prepared(sql: &str) -> Result<(Statement, usize)> {
    let toks = lex(sql)?;
    let mut p = Parser { toks, pos: 0, params: 0 };
    let stmt = p.statement()?;
    p.eat_sym(";"); // optional
    p.expect_eof()?;
    Ok((stmt, p.params))
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
    /// Number of `?` placeholders seen so far (next ordinal to assign).
    params: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos]
    }

    fn next(&mut self) -> Tok {
        let t = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        t
    }

    /// Case-insensitive keyword check without consuming.
    fn peek_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Tok::Ident(s) if s.eq_ignore_ascii_case(kw))
    }

    /// Consume a keyword if present.
    fn eat_kw(&mut self, kw: &str) -> bool {
        if self.peek_kw(kw) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<()> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected keyword {kw}, found {}",
                self.peek().describe()
            )))
        }
    }

    fn eat_sym(&mut self, s: &str) -> bool {
        if matches!(self.peek(), Tok::Sym(x) if *x == s) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect_sym(&mut self, s: &str) -> Result<()> {
        if self.eat_sym(s) {
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "expected '{s}', found {}",
                self.peek().describe()
            )))
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.next() {
            Tok::Ident(s) => Ok(s),
            t => Err(Error::Parse(format!("expected identifier, found {}", t.describe()))),
        }
    }

    fn expect_eof(&mut self) -> Result<()> {
        match self.peek() {
            Tok::Eof => Ok(()),
            t => Err(Error::Parse(format!("trailing input: {}", t.describe()))),
        }
    }

    fn statement(&mut self) -> Result<Statement> {
        if self.peek_kw("SELECT") {
            Ok(Statement::Select(self.select()?))
        } else if self.eat_kw("INSERT") {
            self.insert()
        } else if self.eat_kw("UPDATE") {
            self.update()
        } else if self.eat_kw("DELETE") {
            self.delete()
        } else if self.eat_kw("CREATE") {
            self.create_table()
        } else {
            Err(Error::Parse(format!(
                "expected statement, found {}",
                self.peek().describe()
            )))
        }
    }

    // ---------- SELECT ----------

    fn select(&mut self) -> Result<SelectStmt> {
        self.expect_kw("SELECT")?;
        let items = self.select_items()?;
        self.expect_kw("FROM")?;
        let from = self.table_ref()?;
        let mut joins = Vec::new();
        loop {
            let left_outer = if self.eat_kw("LEFT") {
                self.eat_kw("OUTER");
                self.expect_kw("JOIN")?;
                true
            } else if self.eat_kw("INNER") {
                self.expect_kw("JOIN")?;
                false
            } else if self.eat_kw("JOIN") {
                false
            } else {
                break;
            };
            let table = self.table_ref()?;
            self.expect_kw("ON")?;
            let on = self.expr()?;
            joins.push(Join { table, on, left_outer });
        }
        let where_ = if self.eat_kw("WHERE") { Some(self.expr()?) } else { None };
        let mut group_by = Vec::new();
        if self.eat_kw("GROUP") {
            self.expect_kw("BY")?;
            loop {
                group_by.push(self.expr()?);
                if !self.eat_sym(",") {
                    break;
                }
            }
        }
        let having = if self.eat_kw("HAVING") { Some(self.expr()?) } else { None };
        let order_by = self.order_by()?;
        let limit = self.limit()?;
        Ok(SelectStmt { items, from, joins, where_, group_by, having, order_by, limit })
    }

    fn select_items(&mut self) -> Result<Vec<SelectItem>> {
        let mut items = Vec::new();
        loop {
            if self.eat_sym("*") {
                items.push(SelectItem::Wildcard(None));
            } else {
                // `t.*` looks like Col path; detect before general expr
                let save = self.pos;
                if let Tok::Ident(t) = self.peek().clone() {
                    self.pos += 1;
                    if self.eat_sym(".") && self.eat_sym("*") {
                        items.push(SelectItem::Wildcard(Some(t)));
                        if !self.eat_sym(",") {
                            break;
                        }
                        continue;
                    }
                    self.pos = save;
                }
                let expr = self.expr()?;
                let alias = if self.eat_kw("AS") {
                    Some(self.ident()?)
                } else if matches!(self.peek(), Tok::Ident(s) if !is_clause_kw(s)) {
                    Some(self.ident()?)
                } else {
                    None
                };
                items.push(SelectItem::Expr { expr, alias });
            }
            if !self.eat_sym(",") {
                break;
            }
        }
        Ok(items)
    }

    fn table_ref(&mut self) -> Result<TableRef> {
        let table = self.ident()?;
        let alias = if self.eat_kw("AS") {
            Some(self.ident()?)
        } else if matches!(self.peek(), Tok::Ident(s) if !is_clause_kw(s)) {
            Some(self.ident()?)
        } else {
            None
        };
        Ok(TableRef { table, alias })
    }

    fn order_by(&mut self) -> Result<Vec<(Expr, bool)>> {
        let mut order = Vec::new();
        if self.eat_kw("ORDER") {
            self.expect_kw("BY")?;
            loop {
                let e = self.expr()?;
                let asc = if self.eat_kw("DESC") {
                    false
                } else {
                    self.eat_kw("ASC");
                    true
                };
                order.push((e, asc));
                if !self.eat_sym(",") {
                    break;
                }
            }
        }
        Ok(order)
    }

    fn limit(&mut self) -> Result<Option<u64>> {
        if self.eat_kw("LIMIT") {
            match self.next() {
                Tok::Int(n) if n >= 0 => Ok(Some(n as u64)),
                t => Err(Error::Parse(format!("LIMIT wants a non-negative integer, found {}", t.describe()))),
            }
        } else {
            Ok(None)
        }
    }

    // ---------- INSERT ----------

    fn insert(&mut self) -> Result<Statement> {
        self.expect_kw("INTO")?;
        let table = self.ident()?;
        let mut columns = Vec::new();
        if self.eat_sym("(") {
            loop {
                columns.push(self.ident()?);
                if !self.eat_sym(",") {
                    break;
                }
            }
            self.expect_sym(")")?;
        }
        self.expect_kw("VALUES")?;
        let mut values = Vec::new();
        loop {
            self.expect_sym("(")?;
            let mut row = Vec::new();
            loop {
                row.push(self.expr()?);
                if !self.eat_sym(",") {
                    break;
                }
            }
            self.expect_sym(")")?;
            values.push(row);
            if !self.eat_sym(",") {
                break;
            }
        }
        Ok(Statement::Insert { table, columns, values })
    }

    // ---------- UPDATE ----------

    fn update(&mut self) -> Result<Statement> {
        let table = self.table_ref()?;
        self.expect_kw("SET")?;
        let mut sets = Vec::new();
        loop {
            let col = self.ident()?;
            self.expect_sym("=")?;
            let e = self.expr()?;
            sets.push((col, e));
            if !self.eat_sym(",") {
                break;
            }
        }
        let where_ = if self.eat_kw("WHERE") { Some(self.expr()?) } else { None };
        let order_by = self.order_by()?;
        let limit = self.limit()?;
        let returning = if self.eat_kw("RETURNING") {
            Some(self.select_items()?)
        } else {
            None
        };
        Ok(Statement::Update { table, sets, where_, order_by, limit, returning })
    }

    // ---------- DELETE ----------

    fn delete(&mut self) -> Result<Statement> {
        self.expect_kw("FROM")?;
        let table = self.table_ref()?;
        let where_ = if self.eat_kw("WHERE") { Some(self.expr()?) } else { None };
        Ok(Statement::Delete { table, where_ })
    }

    // ---------- CREATE TABLE ----------

    fn create_table(&mut self) -> Result<Statement> {
        self.expect_kw("TABLE")?;
        let name = self.ident()?;
        self.expect_sym("(")?;
        let mut columns = Vec::new();
        loop {
            let cname = self.ident()?;
            let tyname = self.ident()?;
            let ty = ColumnType::parse(&tyname)?;
            let mut not_null = false;
            if self.eat_kw("NOT") {
                self.expect_kw("NULL")?;
                not_null = true;
            } else {
                self.eat_kw("NULL");
            }
            columns.push(ColumnDecl { name: cname, ty, not_null });
            if !self.eat_sym(",") {
                break;
            }
        }
        self.expect_sym(")")?;
        let mut partition_by = None;
        let mut primary_key = None;
        let mut indexes = Vec::new();
        loop {
            if self.eat_kw("PARTITION") {
                self.expect_kw("BY")?;
                self.expect_kw("HASH")?;
                self.expect_sym("(")?;
                let col = self.ident()?;
                self.expect_sym(")")?;
                self.expect_kw("PARTITIONS")?;
                let n = match self.next() {
                    Tok::Int(n) if n >= 1 => n as usize,
                    t => {
                        return Err(Error::Parse(format!(
                            "PARTITIONS wants a positive integer, found {}",
                            t.describe()
                        )))
                    }
                };
                partition_by = Some((col, n));
            } else if self.eat_kw("PRIMARY") {
                self.expect_kw("KEY")?;
                self.expect_sym("(")?;
                primary_key = Some(self.ident()?);
                self.expect_sym(")")?;
            } else if self.eat_kw("INDEX") {
                self.expect_sym("(")?;
                indexes.push(self.ident()?);
                self.expect_sym(")")?;
            } else {
                break;
            }
        }
        Ok(Statement::CreateTable { name, columns, partition_by, primary_key, indexes })
    }

    // ---------- expressions ----------

    fn expr(&mut self) -> Result<Expr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr> {
        let mut e = self.and_expr()?;
        while self.eat_kw("OR") {
            let rhs = self.and_expr()?;
            e = Expr::Binary(Op::Or, Box::new(e), Box::new(rhs));
        }
        Ok(e)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut e = self.not_expr()?;
        while self.eat_kw("AND") {
            let rhs = self.not_expr()?;
            e = Expr::Binary(Op::And, Box::new(e), Box::new(rhs));
        }
        Ok(e)
    }

    fn not_expr(&mut self) -> Result<Expr> {
        if self.eat_kw("NOT") {
            let e = self.not_expr()?;
            Ok(Expr::Unary(Op::Not, Box::new(e)))
        } else {
            self.predicate()
        }
    }

    /// Comparison layer plus IN / BETWEEN / IS NULL / LIKE postfix forms.
    fn predicate(&mut self) -> Result<Expr> {
        let e = self.add_expr()?;
        // postfix predicates
        let negated = {
            let save = self.pos;
            if self.eat_kw("NOT") {
                if self.peek_kw("IN") || self.peek_kw("BETWEEN") || self.peek_kw("LIKE") {
                    true
                } else {
                    self.pos = save;
                    false
                }
            } else {
                false
            }
        };
        if self.eat_kw("IN") {
            self.expect_sym("(")?;
            let mut list = Vec::new();
            loop {
                list.push(self.expr()?);
                if !self.eat_sym(",") {
                    break;
                }
            }
            self.expect_sym(")")?;
            return Ok(Expr::InList { expr: Box::new(e), list, negated });
        }
        if self.eat_kw("BETWEEN") {
            let lo = self.add_expr()?;
            self.expect_kw("AND")?;
            let hi = self.add_expr()?;
            return Ok(Expr::Between {
                expr: Box::new(e),
                lo: Box::new(lo),
                hi: Box::new(hi),
                negated,
            });
        }
        if self.eat_kw("LIKE") {
            let pattern = match self.next() {
                Tok::Str(s) => s,
                t => {
                    return Err(Error::Parse(format!(
                        "LIKE wants a string literal, found {}",
                        t.describe()
                    )))
                }
            };
            return Ok(Expr::Like { expr: Box::new(e), pattern, negated });
        }
        if self.eat_kw("IS") {
            let negated = self.eat_kw("NOT");
            self.expect_kw("NULL")?;
            return Ok(Expr::IsNull { expr: Box::new(e), negated });
        }
        if negated {
            return Err(Error::Parse("dangling NOT".into()));
        }
        // comparison operators
        let op = if self.eat_sym("=") {
            Some(Op::Eq)
        } else if self.eat_sym("!=") {
            Some(Op::Ne)
        } else if self.eat_sym("<=") {
            Some(Op::Le)
        } else if self.eat_sym("<") {
            Some(Op::Lt)
        } else if self.eat_sym(">=") {
            Some(Op::Ge)
        } else if self.eat_sym(">") {
            Some(Op::Gt)
        } else {
            None
        };
        if let Some(op) = op {
            let rhs = self.add_expr()?;
            return Ok(Expr::Binary(op, Box::new(e), Box::new(rhs)));
        }
        Ok(e)
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut e = self.mul_expr()?;
        loop {
            let op = if self.eat_sym("+") {
                Op::Add
            } else if self.eat_sym("-") {
                Op::Sub
            } else {
                break;
            };
            let rhs = self.mul_expr()?;
            e = Expr::Binary(op, Box::new(e), Box::new(rhs));
        }
        Ok(e)
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut e = self.unary_expr()?;
        loop {
            let op = if self.eat_sym("*") {
                Op::Mul
            } else if self.eat_sym("/") {
                Op::Div
            } else if self.eat_sym("%") {
                Op::Mod
            } else {
                break;
            };
            let rhs = self.unary_expr()?;
            e = Expr::Binary(op, Box::new(e), Box::new(rhs));
        }
        Ok(e)
    }

    fn unary_expr(&mut self) -> Result<Expr> {
        if self.eat_sym("-") {
            let e = self.unary_expr()?;
            return Ok(Expr::Unary(Op::Neg, Box::new(e)));
        }
        if self.eat_sym("+") {
            return self.unary_expr();
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.next() {
            Tok::Int(i) => Ok(Expr::Lit(Value::Int(i))),
            Tok::Float(f) => Ok(Expr::Lit(Value::Float(f))),
            Tok::Str(s) => Ok(Expr::Lit(Value::str(s))),
            Tok::Sym("?") => {
                let ordinal = self.params;
                self.params += 1;
                Ok(Expr::Param(ordinal))
            }
            Tok::Sym("(") => {
                let e = self.expr()?;
                self.expect_sym(")")?;
                Ok(e)
            }
            Tok::Ident(id) => self.ident_expr(id),
            t => Err(Error::Parse(format!("expected expression, found {}", t.describe()))),
        }
    }

    /// An identifier can begin: NULL/TRUE/FALSE literals, CASE, an aggregate,
    /// a scalar function call, or a (qualified) column reference.
    fn ident_expr(&mut self, id: String) -> Result<Expr> {
        let upper = id.to_ascii_uppercase();
        match upper.as_str() {
            "NULL" => return Ok(Expr::Lit(Value::Null)),
            "TRUE" => return Ok(Expr::Lit(Value::Bool(true))),
            "FALSE" => return Ok(Expr::Lit(Value::Bool(false))),
            "CASE" => return self.case_expr(),
            _ => {}
        }
        // aggregate?
        let agg = match upper.as_str() {
            "COUNT" => Some(AggFunc::Count),
            "SUM" => Some(AggFunc::Sum),
            "AVG" => Some(AggFunc::Avg),
            "MIN" => Some(AggFunc::Min),
            "MAX" => Some(AggFunc::Max),
            _ => None,
        };
        if let Some(func) = agg {
            if self.eat_sym("(") {
                let distinct = self.eat_kw("DISTINCT");
                if self.eat_sym("*") {
                    self.expect_sym(")")?;
                    if func != AggFunc::Count {
                        return Err(Error::Parse(format!("{}(*) is not valid", func.name())));
                    }
                    return Ok(Expr::Agg { func, arg: None, distinct });
                }
                let arg = self.expr()?;
                self.expect_sym(")")?;
                return Ok(Expr::Agg { func, arg: Some(Box::new(arg)), distinct });
            }
        }
        // scalar function?
        if matches!(self.peek(), Tok::Sym("(")) {
            self.expect_sym("(")?;
            let mut args = Vec::new();
            if !self.eat_sym(")") {
                loop {
                    args.push(self.expr()?);
                    if !self.eat_sym(",") {
                        break;
                    }
                }
                self.expect_sym(")")?;
            }
            return Ok(Expr::Func { name: upper, args });
        }
        // qualified column?
        if self.eat_sym(".") {
            let col = self.ident()?;
            return Ok(Expr::Col { table: Some(id), name: col });
        }
        Ok(Expr::Col { table: None, name: id })
    }

    fn case_expr(&mut self) -> Result<Expr> {
        let mut arms = Vec::new();
        while self.eat_kw("WHEN") {
            let c = self.expr()?;
            self.expect_kw("THEN")?;
            let v = self.expr()?;
            arms.push((c, v));
        }
        if arms.is_empty() {
            return Err(Error::Parse("CASE needs at least one WHEN arm".into()));
        }
        let else_ = if self.eat_kw("ELSE") { Some(Box::new(self.expr()?)) } else { None };
        self.expect_kw("END")?;
        Ok(Expr::Case { arms, else_ })
    }
}

/// Keywords that terminate an implicit alias position.
fn is_clause_kw(s: &str) -> bool {
    const KWS: &[&str] = &[
        "FROM", "WHERE", "GROUP", "HAVING", "ORDER", "LIMIT", "JOIN", "LEFT", "INNER", "OUTER",
        "ON", "SET", "VALUES", "RETURNING", "AND", "OR", "NOT", "AS", "ASC", "DESC", "BY",
        "PARTITION", "PRIMARY", "INDEX", "UNION",
    ];
    KWS.iter().any(|k| s.eq_ignore_ascii_case(k))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel(sql: &str) -> SelectStmt {
        match parse_statement(sql).unwrap() {
            Statement::Select(s) => s,
            other => panic!("not a select: {other:?}"),
        }
    }

    #[test]
    fn select_with_everything() {
        let s = sel(
            "SELECT w.node AS host, COUNT(*) n, AVG(t.dur) FROM tasks t \
             LEFT JOIN workers w ON t.wid = w.id \
             WHERE t.status = 'FINISHED' AND t.endt >= NOW() - 60 \
             GROUP BY w.node HAVING COUNT(*) > 1 \
             ORDER BY n DESC, host LIMIT 5",
        );
        assert_eq!(s.items.len(), 3);
        assert_eq!(s.joins.len(), 1);
        assert!(s.joins[0].left_outer);
        assert_eq!(s.group_by.len(), 1);
        assert!(s.having.is_some());
        assert_eq!(s.order_by.len(), 2);
        assert!(!s.order_by[0].1); // DESC
        assert!(s.order_by[1].1); // implicit ASC
        assert_eq!(s.limit, Some(5));
    }

    #[test]
    fn implicit_and_explicit_alias() {
        let s = sel("SELECT a x, b AS y FROM t");
        match &s.items[0] {
            SelectItem::Expr { alias, .. } => assert_eq!(alias.as_deref(), Some("x")),
            _ => panic!(),
        }
        match &s.items[1] {
            SelectItem::Expr { alias, .. } => assert_eq!(alias.as_deref(), Some("y")),
            _ => panic!(),
        }
    }

    #[test]
    fn qualified_wildcard() {
        let s = sel("SELECT t.*, u.a FROM t JOIN u ON t.x = u.x");
        assert!(matches!(&s.items[0], SelectItem::Wildcard(Some(q)) if q == "t"));
    }

    #[test]
    fn update_with_limit_returning() {
        let st = parse_statement(
            "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
             WHERE workerid = 3 AND status = 'READY' ORDER BY taskid LIMIT 4 \
             RETURNING taskid, cmd",
        )
        .unwrap();
        match st {
            Statement::Update { sets, where_, order_by, limit, returning, .. } => {
                assert_eq!(sets.len(), 2);
                assert!(where_.is_some());
                assert_eq!(order_by.len(), 1);
                assert_eq!(limit, Some(4));
                assert_eq!(returning.unwrap().len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn create_table_full_clause() {
        let st = parse_statement(
            "CREATE TABLE wq (taskid INT NOT NULL, wid INT, s TEXT) \
             PARTITION BY HASH(wid) PARTITIONS 8 PRIMARY KEY (taskid) INDEX (s)",
        )
        .unwrap();
        match st {
            Statement::CreateTable { name, columns, partition_by, primary_key, indexes } => {
                assert_eq!(name, "wq");
                assert_eq!(columns.len(), 3);
                assert!(columns[0].not_null);
                assert_eq!(partition_by, Some(("wid".into(), 8)));
                assert_eq!(primary_key.as_deref(), Some("taskid"));
                assert_eq!(indexes, vec!["s".to_string()]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn expression_precedence() {
        // a + b * c parses as a + (b*c)
        let s = sel("SELECT a + b * c FROM t");
        match &s.items[0] {
            SelectItem::Expr { expr: Expr::Binary(Op::Add, _, rhs), .. } => {
                assert!(matches!(rhs.as_ref(), Expr::Binary(Op::Mul, _, _)));
            }
            other => panic!("{other:?}"),
        }
        // OR binds looser than AND
        let s = sel("SELECT * FROM t WHERE a AND b OR c");
        match s.where_.unwrap() {
            Expr::Binary(Op::Or, lhs, _) => {
                assert!(matches!(lhs.as_ref(), Expr::Binary(Op::And, _, _)));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn predicates_in_between_like_isnull_not() {
        parse_statement("SELECT * FROM t WHERE a IN (1,2,3) AND b NOT IN (4)").unwrap();
        parse_statement("SELECT * FROM t WHERE a BETWEEN 1 AND 5 OR a NOT BETWEEN 8 AND 9")
            .unwrap();
        parse_statement("SELECT * FROM t WHERE s LIKE 'REA%' AND u NOT LIKE '%x_'").unwrap();
        parse_statement("SELECT * FROM t WHERE e IS NULL AND f IS NOT NULL").unwrap();
        parse_statement("SELECT * FROM t WHERE NOT (a = 1)").unwrap();
    }

    #[test]
    fn case_expression() {
        let s = sel("SELECT CASE WHEN a > 0 THEN 'pos' WHEN a < 0 THEN 'neg' ELSE 'zero' END FROM t");
        match &s.items[0] {
            SelectItem::Expr { expr: Expr::Case { arms, else_ }, .. } => {
                assert_eq!(arms.len(), 2);
                assert!(else_.is_some());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_errors_are_reported() {
        assert!(parse_statement("SELECT FROM t").is_err());
        assert!(parse_statement("UPDATE t").is_err());
        assert!(parse_statement("SELECT * FROM t WHERE a LIKE 5").is_err());
        assert!(parse_statement("SELECT * FROM t LIMIT -1").is_err());
        assert!(parse_statement("SELECT * FROM t extra garbage !").is_err());
        assert!(parse_statement("SELECT SUM(*) FROM t").is_err());
    }

    #[test]
    fn parameters_get_sequential_ordinals() {
        let (stmt, n) = parse_prepared(
            "UPDATE workqueue SET status = ?, starttime = NOW() \
             WHERE workerid = ? AND status = ? ORDER BY taskid LIMIT 4",
        )
        .unwrap();
        assert_eq!(n, 3);
        match stmt {
            Statement::Update { sets, where_, .. } => {
                assert_eq!(sets[0].1, Expr::Param(0));
                let conj = where_.unwrap();
                let cs = conj.conjuncts().into_iter().cloned().collect::<Vec<_>>();
                assert!(cs.iter().any(|c| matches!(
                    c,
                    Expr::Binary(Op::Eq, _, b) if **b == Expr::Param(1)
                )));
                assert!(cs.iter().any(|c| matches!(
                    c,
                    Expr::Binary(Op::Eq, _, b) if **b == Expr::Param(2)
                )));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parameters_span_multi_row_insert() {
        let (stmt, n) =
            parse_prepared("INSERT INTO t (a, b) VALUES (?, ?), (?, ?)").unwrap();
        assert_eq!(n, 4);
        match stmt {
            Statement::Insert { values, .. } => {
                assert_eq!(values.len(), 2);
                assert_eq!(values[1][0], Expr::Param(2));
                assert_eq!(values[1][1], Expr::Param(3));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn count_distinct() {
        let s = sel("SELECT COUNT(DISTINCT wid) FROM t");
        match &s.items[0] {
            SelectItem::Expr { expr: Expr::Agg { distinct, .. }, .. } => assert!(distinct),
            other => panic!("{other:?}"),
        }
    }
}
