//! SQL abstract syntax tree.

use crate::storage::value::{ColumnType, Value};

/// Binary/unary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
    Not,
    Neg,
}

/// Aggregate functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Count,
    Sum,
    Avg,
    Min,
    Max,
}

impl AggFunc {
    pub fn name(self) -> &'static str {
        match self {
            AggFunc::Count => "COUNT",
            AggFunc::Sum => "SUM",
            AggFunc::Avg => "AVG",
            AggFunc::Min => "MIN",
            AggFunc::Max => "MAX",
        }
    }
}

/// Expression tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Lit(Value),
    /// `?` placeholder of a prepared statement; the ordinal is assigned
    /// left-to-right at parse time (0-based). `Prepared::bind` replaces
    /// every `Param` with the bound literal before execution, so partition
    /// pruning and index probes see plain `Lit` nodes.
    Param(usize),
    /// Column reference, optionally qualified: `t.col` or `col`.
    Col { table: Option<String>, name: String },
    Unary(Op, Box<Expr>),
    Binary(Op, Box<Expr>, Box<Expr>),
    /// Scalar function call: NOW(), COALESCE(a,b), ABS(x), ROUND(x, n),
    /// LENGTH(s), UPPER(s), LOWER(s).
    Func { name: String, args: Vec<Expr> },
    /// Aggregate call; `arg = None` means `COUNT(*)`.
    Agg { func: AggFunc, arg: Option<Box<Expr>>, distinct: bool },
    /// `e [NOT] IN (v1, v2, ...)`
    InList { expr: Box<Expr>, list: Vec<Expr>, negated: bool },
    /// `e [NOT] BETWEEN lo AND hi`
    Between { expr: Box<Expr>, lo: Box<Expr>, hi: Box<Expr>, negated: bool },
    /// `e IS [NOT] NULL`
    IsNull { expr: Box<Expr>, negated: bool },
    /// `e [NOT] LIKE 'pat%'`
    Like { expr: Box<Expr>, pattern: String, negated: bool },
    /// `CASE WHEN c THEN v [WHEN ...] [ELSE v] END`
    Case { arms: Vec<(Expr, Expr)>, else_: Option<Box<Expr>> },
}

impl Expr {
    pub fn col(name: &str) -> Expr {
        Expr::Col { table: None, name: name.to_string() }
    }

    pub fn lit(v: Value) -> Expr {
        Expr::Lit(v)
    }

    /// Does the expression contain any aggregate call?
    pub fn has_aggregate(&self) -> bool {
        match self {
            Expr::Agg { .. } => true,
            Expr::Lit(_) | Expr::Param(_) | Expr::Col { .. } => false,
            Expr::Unary(_, e) => e.has_aggregate(),
            Expr::Binary(_, a, b) => a.has_aggregate() || b.has_aggregate(),
            Expr::Func { args, .. } => args.iter().any(|e| e.has_aggregate()),
            Expr::InList { expr, list, .. } => {
                expr.has_aggregate() || list.iter().any(|e| e.has_aggregate())
            }
            Expr::Between { expr, lo, hi, .. } => {
                expr.has_aggregate() || lo.has_aggregate() || hi.has_aggregate()
            }
            Expr::IsNull { expr, .. } => expr.has_aggregate(),
            Expr::Like { expr, .. } => expr.has_aggregate(),
            Expr::Case { arms, else_ } => {
                arms.iter().any(|(c, v)| c.has_aggregate() || v.has_aggregate())
                    || else_.as_ref().map_or(false, |e| e.has_aggregate())
            }
        }
    }

    /// Collect the conjuncts of a top-level AND chain (for partition
    /// pruning and index selection).
    pub fn conjuncts(&self) -> Vec<&Expr> {
        match self {
            Expr::Binary(Op::And, a, b) => {
                let mut v = a.conjuncts();
                v.extend(b.conjuncts());
                v
            }
            e => vec![e],
        }
    }

    /// If this conjunct pins `column = <int literal>`, return (name, key).
    /// Used for routing `worker_id = i` to its partition.
    pub fn as_int_eq(&self) -> Option<(&str, i64)> {
        if let Expr::Binary(Op::Eq, a, b) = self {
            let (col, lit) = match (a.as_ref(), b.as_ref()) {
                (Expr::Col { name, .. }, Expr::Lit(Value::Int(k))) => (name.as_str(), *k),
                (Expr::Lit(Value::Int(k)), Expr::Col { name, .. }) => (name.as_str(), *k),
                _ => return None,
            };
            return Some((col, lit));
        }
        None
    }

    /// If this conjunct pins `column = <literal>` (any literal type),
    /// return (name, value). Used for secondary-index lookups.
    pub fn as_lit_eq(&self) -> Option<(&str, &Value)> {
        if let Expr::Binary(Op::Eq, a, b) = self {
            let (col, lit) = match (a.as_ref(), b.as_ref()) {
                (Expr::Col { name, .. }, Expr::Lit(v)) => (name.as_str(), v),
                (Expr::Lit(v), Expr::Col { name, .. }) => (name.as_str(), v),
                _ => return None,
            };
            return Some((col, lit));
        }
        None
    }
}

/// One output item of a SELECT list.
#[derive(Clone, Debug, PartialEq)]
pub enum SelectItem {
    /// `*` (optionally `t.*`)
    Wildcard(Option<String>),
    Expr { expr: Expr, alias: Option<String> },
}

/// Table reference with optional alias.
#[derive(Clone, Debug, PartialEq)]
pub struct TableRef {
    pub table: String,
    pub alias: Option<String>,
}

impl TableRef {
    /// Name the reference binds to in scope (alias wins).
    pub fn binding(&self) -> &str {
        self.alias.as_deref().unwrap_or(&self.table)
    }
}

/// Join clause.
#[derive(Clone, Debug, PartialEq)]
pub struct Join {
    pub table: TableRef,
    pub on: Expr,
    pub left_outer: bool,
}

/// `SELECT` statement.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectStmt {
    pub items: Vec<SelectItem>,
    pub from: TableRef,
    pub joins: Vec<Join>,
    pub where_: Option<Expr>,
    pub group_by: Vec<Expr>,
    pub having: Option<Expr>,
    pub order_by: Vec<(Expr, bool)>, // (expr, ascending)
    pub limit: Option<u64>,
}

/// Column clause of CREATE TABLE.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnDecl {
    pub name: String,
    pub ty: ColumnType,
    pub not_null: bool,
}

/// Any statement.
#[derive(Clone, Debug, PartialEq)]
pub enum Statement {
    CreateTable {
        name: String,
        columns: Vec<ColumnDecl>,
        /// PARTITION BY HASH(col) PARTITIONS n
        partition_by: Option<(String, usize)>,
        primary_key: Option<String>,
        indexes: Vec<String>,
    },
    Insert {
        table: String,
        columns: Vec<String>,
        values: Vec<Vec<Expr>>,
    },
    Select(SelectStmt),
    Update {
        table: TableRef,
        sets: Vec<(String, Expr)>,
        where_: Option<Expr>,
        order_by: Vec<(Expr, bool)>,
        limit: Option<u64>,
        returning: Option<Vec<SelectItem>>,
    },
    Delete {
        table: TableRef,
        where_: Option<Expr>,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conjuncts_flatten_and_chain() {
        let e = Expr::Binary(
            Op::And,
            Box::new(Expr::Binary(
                Op::And,
                Box::new(Expr::col("a")),
                Box::new(Expr::col("b")),
            )),
            Box::new(Expr::col("c")),
        );
        assert_eq!(e.conjuncts().len(), 3);
    }

    #[test]
    fn int_eq_detection_both_orders() {
        let e = Expr::Binary(
            Op::Eq,
            Box::new(Expr::col("workerid")),
            Box::new(Expr::Lit(Value::Int(7))),
        );
        assert_eq!(e.as_int_eq(), Some(("workerid", 7)));
        let e2 = Expr::Binary(
            Op::Eq,
            Box::new(Expr::Lit(Value::Int(7))),
            Box::new(Expr::col("workerid")),
        );
        assert_eq!(e2.as_int_eq(), Some(("workerid", 7)));
        let ne = Expr::Binary(
            Op::Ne,
            Box::new(Expr::col("workerid")),
            Box::new(Expr::Lit(Value::Int(7))),
        );
        assert_eq!(ne.as_int_eq(), None);
    }

    #[test]
    fn aggregate_detection_recurses() {
        let agg = Expr::Agg { func: AggFunc::Count, arg: None, distinct: false };
        let e = Expr::Binary(Op::Gt, Box::new(agg), Box::new(Expr::Lit(Value::Int(2))));
        assert!(e.has_aggregate());
        assert!(!Expr::col("x").has_aggregate());
    }
}
