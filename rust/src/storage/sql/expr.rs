//! Expression binding and evaluation.
//!
//! Expressions are *bound* once per statement against a column [`Layout`]
//! (name → position), producing a [`Bound`] tree that evaluates over plain
//! `&[Value]` slices with no name lookups — scans evaluate the predicate per
//! row, so this is the engine's innermost loop.

use super::ast::{Expr, Op};
use crate::storage::value::Value;
use crate::{Error, Result};
use regex::Regex;
use std::cmp::Ordering;

/// Column layout of the row stream an expression runs against. Each column
/// has an optional binding qualifier (table name or alias) plus its name;
/// join outputs concatenate the layouts of their inputs.
#[derive(Clone, Debug, Default)]
pub struct Layout {
    pub cols: Vec<(Option<String>, String)>,
}

impl Layout {
    pub fn new(cols: Vec<(Option<String>, String)>) -> Layout {
        Layout { cols }
    }

    /// Layout of a single table: every column qualified by `binding`.
    pub fn of_table(binding: &str, col_names: impl IntoIterator<Item = String>) -> Layout {
        Layout {
            cols: col_names
                .into_iter()
                .map(|c| (Some(binding.to_string()), c))
                .collect(),
        }
    }

    /// Concatenate (join output).
    pub fn join(&self, other: &Layout) -> Layout {
        let mut cols = self.cols.clone();
        cols.extend(other.cols.iter().cloned());
        Layout { cols }
    }

    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Resolve a column reference; ambiguity and misses are errors.
    pub fn resolve(&self, table: Option<&str>, name: &str) -> Result<usize> {
        let mut hit = None;
        for (i, (q, c)) in self.cols.iter().enumerate() {
            let name_ok = c.eq_ignore_ascii_case(name);
            let qual_ok = match (table, q) {
                (Some(t), Some(q)) => t.eq_ignore_ascii_case(q),
                (Some(_), None) => false,
                (None, _) => true,
            };
            if name_ok && qual_ok {
                if hit.is_some() {
                    return Err(Error::Type(format!("ambiguous column '{name}'")));
                }
                hit = Some(i);
            }
        }
        hit.ok_or_else(|| {
            let q = table.map(|t| format!("{t}.")).unwrap_or_default();
            Error::Type(format!("unknown column '{q}{name}'"))
        })
    }
}

/// Evaluation context (values that are per-statement, not per-row).
#[derive(Clone, Copy, Debug)]
pub struct EvalCtx {
    /// Statement start time in engine seconds; `NOW()` is stable within a
    /// statement, as in real DBMSs.
    pub now: f64,
}

/// Scalar functions known to the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FuncKind {
    Now,
    Coalesce,
    Abs,
    Round,
    Length,
    Upper,
    Lower,
    Sqrt,
    Floor,
    Ceil,
    Concat,
}

/// A bound (name-resolved, pattern-compiled) expression.
pub enum Bound {
    Lit(Value),
    Col(usize),
    /// Fast path for `column <op> literal` — the scheduler's hot predicates
    /// (`workerid = i AND status = 'READY'`) evaluate without cloning
    /// either side.
    ColCmp { col: usize, op: Op, lit: Value },
    Unary(Op, Box<Bound>),
    Binary(Op, Box<Bound>, Box<Bound>),
    Func(FuncKindBox),
    InList { expr: Box<Bound>, list: Vec<Bound>, negated: bool },
    Between { expr: Box<Bound>, lo: Box<Bound>, hi: Box<Bound>, negated: bool },
    IsNull { expr: Box<Bound>, negated: bool },
    Like { expr: Box<Bound>, re: Regex, negated: bool },
    Case { arms: Vec<(Bound, Bound)>, else_: Option<Box<Bound>> },
}

/// Function call payload (kept out of the enum for readability).
pub struct FuncKindBox {
    kind: FuncKind,
    args: Vec<Bound>,
}

/// Bind `expr` against `layout`. Aggregate nodes must have been rewritten
/// into column references beforehand (see `exec::rewrite_aggregates`);
/// encountering one here is an internal error.
pub fn bind(expr: &Expr, layout: &Layout) -> Result<Bound> {
    Ok(match expr {
        Expr::Lit(v) => Bound::Lit(v.clone()),
        Expr::Param(i) => {
            // Parameters are substituted with bound literals by
            // `Prepared::bind` before execution; one reaching the row
            // evaluator means the statement was executed unbound.
            return Err(Error::Type(format!(
                "unbound parameter ?{i} (prepare the statement and bind values before executing)"
            )));
        }
        Expr::Col { table, name } => Bound::Col(layout.resolve(table.as_deref(), name)?),
        Expr::Unary(op, e) => Bound::Unary(*op, Box::new(bind(e, layout)?)),
        Expr::Binary(op, a, b) => {
            // comparison against a literal compiles to the no-clone form
            if matches!(op, Op::Eq | Op::Ne | Op::Lt | Op::Le | Op::Gt | Op::Ge) {
                match (a.as_ref(), b.as_ref()) {
                    (Expr::Col { table, name }, Expr::Lit(v)) => {
                        return Ok(Bound::ColCmp {
                            col: layout.resolve(table.as_deref(), name)?,
                            op: *op,
                            lit: v.clone(),
                        })
                    }
                    (Expr::Lit(v), Expr::Col { table, name }) => {
                        return Ok(Bound::ColCmp {
                            col: layout.resolve(table.as_deref(), name)?,
                            op: flip(*op),
                            lit: v.clone(),
                        })
                    }
                    _ => {}
                }
            }
            Bound::Binary(*op, Box::new(bind(a, layout)?), Box::new(bind(b, layout)?))
        }
        Expr::Func { name, args } => {
            let kind = match name.as_str() {
                "NOW" => FuncKind::Now,
                "COALESCE" | "IFNULL" => FuncKind::Coalesce,
                "ABS" => FuncKind::Abs,
                "ROUND" => FuncKind::Round,
                "LENGTH" => FuncKind::Length,
                "UPPER" => FuncKind::Upper,
                "LOWER" => FuncKind::Lower,
                "SQRT" => FuncKind::Sqrt,
                "FLOOR" => FuncKind::Floor,
                "CEIL" => FuncKind::Ceil,
                "CONCAT" => FuncKind::Concat,
                other => return Err(Error::Type(format!("unknown function {other}()"))),
            };
            let args = args.iter().map(|a| bind(a, layout)).collect::<Result<Vec<_>>>()?;
            Bound::Func(FuncKindBox { kind, args })
        }
        Expr::Agg { .. } => {
            return Err(Error::Type(
                "aggregate in row context (missing GROUP BY rewrite)".into(),
            ))
        }
        Expr::InList { expr, list, negated } => Bound::InList {
            expr: Box::new(bind(expr, layout)?),
            list: list.iter().map(|e| bind(e, layout)).collect::<Result<Vec<_>>>()?,
            negated: *negated,
        },
        Expr::Between { expr, lo, hi, negated } => Bound::Between {
            expr: Box::new(bind(expr, layout)?),
            lo: Box::new(bind(lo, layout)?),
            hi: Box::new(bind(hi, layout)?),
            negated: *negated,
        },
        Expr::IsNull { expr, negated } => {
            Bound::IsNull { expr: Box::new(bind(expr, layout)?), negated: *negated }
        }
        Expr::Like { expr, pattern, negated } => Bound::Like {
            expr: Box::new(bind(expr, layout)?),
            re: like_to_regex(pattern)?,
            negated: *negated,
        },
        Expr::Case { arms, else_ } => Bound::Case {
            arms: arms
                .iter()
                .map(|(c, v)| Ok((bind(c, layout)?, bind(v, layout)?)))
                .collect::<Result<Vec<_>>>()?,
            else_: match else_ {
                Some(e) => Some(Box::new(bind(e, layout)?)),
                None => None,
            },
        },
    })
}

/// Mirror a comparison operator (for `lit op col` → `col op' lit`).
fn flip(op: Op) -> Op {
    match op {
        Op::Lt => Op::Gt,
        Op::Le => Op::Ge,
        Op::Gt => Op::Lt,
        Op::Ge => Op::Le,
        other => other,
    }
}

/// Translate a SQL LIKE pattern to an anchored regex.
fn like_to_regex(pattern: &str) -> Result<Regex> {
    let mut re = String::with_capacity(pattern.len() + 8);
    re.push('^');
    for c in pattern.chars() {
        match c {
            '%' => re.push_str(".*"),
            '_' => re.push('.'),
            c => re.push_str(&regex::escape(&c.to_string())),
        }
    }
    re.push('$');
    Regex::new(&re).map_err(|e| Error::Parse(format!("bad LIKE pattern '{pattern}': {e}")))
}

impl Bound {
    /// Evaluate over one row.
    pub fn eval(&self, row: &[Value], ctx: &EvalCtx) -> Result<Value> {
        Ok(match self {
            Bound::Lit(v) => v.clone(),
            Bound::Col(i) => row[*i].clone(),
            Bound::ColCmp { col, op, lit } => match row[*col].sql_cmp(lit) {
                None => Value::Null,
                Some(o) => Value::Bool(match op {
                    Op::Eq => o == Ordering::Equal,
                    Op::Ne => o != Ordering::Equal,
                    Op::Lt => o == Ordering::Less,
                    Op::Le => o != Ordering::Greater,
                    Op::Gt => o == Ordering::Greater,
                    Op::Ge => o != Ordering::Less,
                    _ => unreachable!("non-comparison in ColCmp"),
                }),
            },
            Bound::Unary(op, e) => {
                let v = e.eval(row, ctx)?;
                match op {
                    Op::Not => match truthy(&v)? {
                        None => Value::Null,
                        Some(b) => Value::Bool(!b),
                    },
                    Op::Neg => match v {
                        Value::Null => Value::Null,
                        Value::Int(i) => Value::Int(-i),
                        Value::Float(f) => Value::Float(-f),
                        other => return Err(Error::Type(format!("cannot negate {other}"))),
                    },
                    other => return Err(Error::Type(format!("bad unary op {other:?}"))),
                }
            }
            Bound::Binary(op, a, b) => {
                match op {
                    Op::And => {
                        // 3VL short-circuit: false AND x = false
                        let l = truthy(&a.eval(row, ctx)?)?;
                        if l == Some(false) {
                            return Ok(Value::Bool(false));
                        }
                        let r = truthy(&b.eval(row, ctx)?)?;
                        return Ok(match (l, r) {
                            (_, Some(false)) => Value::Bool(false),
                            (Some(true), Some(true)) => Value::Bool(true),
                            _ => Value::Null,
                        });
                    }
                    Op::Or => {
                        let l = truthy(&a.eval(row, ctx)?)?;
                        if l == Some(true) {
                            return Ok(Value::Bool(true));
                        }
                        let r = truthy(&b.eval(row, ctx)?)?;
                        return Ok(match (l, r) {
                            (_, Some(true)) => Value::Bool(true),
                            (Some(false), Some(false)) => Value::Bool(false),
                            _ => Value::Null,
                        });
                    }
                    _ => {}
                }
                let l = a.eval(row, ctx)?;
                let r = b.eval(row, ctx)?;
                match op {
                    Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Mod => arith(*op, &l, &r)?,
                    Op::Eq | Op::Ne | Op::Lt | Op::Le | Op::Gt | Op::Ge => {
                        match l.sql_cmp(&r) {
                            None => Value::Null,
                            Some(o) => Value::Bool(match op {
                                Op::Eq => o == Ordering::Equal,
                                Op::Ne => o != Ordering::Equal,
                                Op::Lt => o == Ordering::Less,
                                Op::Le => o != Ordering::Greater,
                                Op::Gt => o == Ordering::Greater,
                                Op::Ge => o != Ordering::Less,
                                _ => unreachable!(),
                            }),
                        }
                    }
                    other => return Err(Error::Type(format!("bad binary op {other:?}"))),
                }
            }
            Bound::Func(f) => eval_func(f, row, ctx)?,
            Bound::InList { expr, list, negated } => {
                let v = expr.eval(row, ctx)?;
                if v.is_null() {
                    return Ok(Value::Null);
                }
                let mut saw_null = false;
                let mut found = false;
                for item in list {
                    let iv = item.eval(row, ctx)?;
                    match v.sql_eq(&iv) {
                        None => saw_null = true,
                        Some(true) => {
                            found = true;
                            break;
                        }
                        Some(false) => {}
                    }
                }
                if found {
                    Value::Bool(!negated)
                } else if saw_null {
                    Value::Null
                } else {
                    Value::Bool(*negated)
                }
            }
            Bound::Between { expr, lo, hi, negated } => {
                let v = expr.eval(row, ctx)?;
                let l = lo.eval(row, ctx)?;
                let h = hi.eval(row, ctx)?;
                match (v.sql_cmp(&l), v.sql_cmp(&h)) {
                    (Some(a), Some(b)) => {
                        let inside = a != Ordering::Less && b != Ordering::Greater;
                        Value::Bool(inside != *negated)
                    }
                    _ => Value::Null,
                }
            }
            Bound::IsNull { expr, negated } => {
                let v = expr.eval(row, ctx)?;
                Value::Bool(v.is_null() != *negated)
            }
            Bound::Like { expr, re, negated } => {
                let v = expr.eval(row, ctx)?;
                match v {
                    Value::Null => Value::Null,
                    Value::Str(s) => Value::Bool(re.is_match(&s) != *negated),
                    other => return Err(Error::Type(format!("LIKE on non-string {other}"))),
                }
            }
            Bound::Case { arms, else_ } => {
                for (c, v) in arms {
                    if truthy(&c.eval(row, ctx)?)? == Some(true) {
                        return v.eval(row, ctx);
                    }
                }
                match else_ {
                    Some(e) => e.eval(row, ctx)?,
                    None => Value::Null,
                }
            }
        })
    }

    /// Evaluate as a WHERE predicate: NULL counts as not-matching.
    pub fn matches(&self, row: &[Value], ctx: &EvalCtx) -> Result<bool> {
        Ok(truthy(&self.eval(row, ctx)?)? == Some(true))
    }
}

/// SQL truthiness: Bool→Some(b), Null→None, anything else is a type error.
/// Shared with the compiled DML evaluator (`storage::dml_plan`), which must
/// agree with the interpreter on 3VL semantics.
pub(crate) fn truthy(v: &Value) -> Result<Option<bool>> {
    match v {
        Value::Bool(b) => Ok(Some(*b)),
        Value::Null => Ok(None),
        other => Err(Error::Type(format!("expected boolean, got {other}"))),
    }
}

/// Arithmetic with MySQL-style coercions. Shared with the compiled DML
/// evaluator so `SET failtries = failtries + 1` computes identically on
/// both execution paths.
pub(crate) fn arith(op: Op, l: &Value, r: &Value) -> Result<Value> {
    if l.is_null() || r.is_null() {
        return Ok(Value::Null);
    }
    // CONCAT-style string + is not supported; arithmetic is numeric only.
    let both_int = matches!(l, Value::Int(_)) && matches!(r, Value::Int(_));
    let (a, b) = match (l.as_f64(), r.as_f64()) {
        (Some(a), Some(b)) => (a, b),
        _ => return Err(Error::Type(format!("arithmetic on non-numeric: {l} {op:?} {r}"))),
    };
    Ok(match op {
        Op::Add if both_int => Value::Int(l.as_i64().unwrap().wrapping_add(r.as_i64().unwrap())),
        Op::Sub if both_int => Value::Int(l.as_i64().unwrap().wrapping_sub(r.as_i64().unwrap())),
        Op::Mul if both_int => Value::Int(l.as_i64().unwrap().wrapping_mul(r.as_i64().unwrap())),
        Op::Add => Value::Float(a + b),
        Op::Sub => Value::Float(a - b),
        Op::Mul => Value::Float(a * b),
        // Division is always float (MySQL semantics); x/0 is NULL.
        Op::Div => {
            if b == 0.0 {
                Value::Null
            } else {
                Value::Float(a / b)
            }
        }
        Op::Mod => {
            if both_int {
                let bi = r.as_i64().unwrap();
                if bi == 0 {
                    Value::Null
                } else {
                    Value::Int(l.as_i64().unwrap().rem_euclid(bi))
                }
            } else if b == 0.0 {
                Value::Null
            } else {
                Value::Float(a.rem_euclid(b))
            }
        }
        _ => unreachable!(),
    })
}

fn eval_func(f: &FuncKindBox, row: &[Value], ctx: &EvalCtx) -> Result<Value> {
    let need = |n: usize| -> Result<()> {
        if f.args.len() == n {
            Ok(())
        } else {
            Err(Error::Type(format!("{:?} wants {n} args, got {}", f.kind, f.args.len())))
        }
    };
    Ok(match f.kind {
        FuncKind::Now => {
            need(0)?;
            Value::Float(ctx.now)
        }
        FuncKind::Coalesce => {
            for a in &f.args {
                let v = a.eval(row, ctx)?;
                if !v.is_null() {
                    return Ok(v);
                }
            }
            Value::Null
        }
        FuncKind::Abs => {
            need(1)?;
            match f.args[0].eval(row, ctx)? {
                Value::Null => Value::Null,
                Value::Int(i) => Value::Int(i.abs()),
                Value::Float(x) => Value::Float(x.abs()),
                other => return Err(Error::Type(format!("ABS on {other}"))),
            }
        }
        FuncKind::Round => {
            if f.args.is_empty() || f.args.len() > 2 {
                return Err(Error::Type("ROUND wants 1 or 2 args".into()));
            }
            let v = f.args[0].eval(row, ctx)?;
            let digits = if f.args.len() == 2 {
                f.args[1].eval(row, ctx)?.as_i64().unwrap_or(0)
            } else {
                0
            };
            match v {
                Value::Null => Value::Null,
                v => {
                    let x = v
                        .as_f64()
                        .ok_or_else(|| Error::Type(format!("ROUND on {v}")))?;
                    let m = 10f64.powi(digits as i32);
                    Value::Float((x * m).round() / m)
                }
            }
        }
        FuncKind::Length => {
            need(1)?;
            match f.args[0].eval(row, ctx)? {
                Value::Null => Value::Null,
                Value::Str(s) => Value::Int(s.chars().count() as i64),
                other => return Err(Error::Type(format!("LENGTH on {other}"))),
            }
        }
        FuncKind::Upper | FuncKind::Lower => {
            need(1)?;
            match f.args[0].eval(row, ctx)? {
                Value::Null => Value::Null,
                Value::Str(s) => {
                    if f.kind == FuncKind::Upper {
                        Value::str(s.to_uppercase())
                    } else {
                        Value::str(s.to_lowercase())
                    }
                }
                other => return Err(Error::Type(format!("case function on {other}"))),
            }
        }
        FuncKind::Sqrt | FuncKind::Floor | FuncKind::Ceil => {
            need(1)?;
            let v = f.args[0].eval(row, ctx)?;
            if v.is_null() {
                return Ok(Value::Null);
            }
            let x = v
                .as_f64()
                .ok_or_else(|| Error::Type(format!("{:?} on {v}", f.kind)))?;
            match f.kind {
                FuncKind::Sqrt => Value::Float(x.sqrt()),
                FuncKind::Floor => Value::Float(x.floor()),
                FuncKind::Ceil => Value::Float(x.ceil()),
                _ => unreachable!(),
            }
        }
        FuncKind::Concat => {
            let mut s = String::new();
            for a in &f.args {
                let v = a.eval(row, ctx)?;
                if v.is_null() {
                    return Ok(Value::Null);
                }
                s.push_str(&v.to_string());
            }
            Value::str(s)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::sql::parse;
    use crate::storage::sql::Statement;

    fn ctx() -> EvalCtx {
        EvalCtx { now: 1000.0 }
    }

    /// Parse `SELECT <expr> FROM t`, bind against the given layout, eval.
    fn eval_expr(src: &str, layout: &Layout, row: &[Value]) -> Result<Value> {
        let sql = format!("SELECT {src} FROM t");
        let stmt = parse(&sql)?;
        let e = match stmt {
            Statement::Select(s) => match s.items.into_iter().next().unwrap() {
                super::super::ast::SelectItem::Expr { expr, .. } => expr,
                _ => panic!(),
            },
            _ => panic!(),
        };
        bind(&e, layout)?.eval(row, &ctx())
    }

    fn layout() -> Layout {
        Layout::of_table("t", ["a", "b", "s"].map(String::from))
    }

    #[test]
    fn arithmetic_int_float_and_nulls() {
        let l = layout();
        let row = [Value::Int(6), Value::Float(1.5), Value::str("READY")];
        assert_eq!(eval_expr("a + 2", &l, &row).unwrap(), Value::Int(8));
        assert_eq!(eval_expr("a + b", &l, &row).unwrap(), Value::Float(7.5));
        assert_eq!(eval_expr("a / 4", &l, &row).unwrap(), Value::Float(1.5));
        assert_eq!(eval_expr("a / 0", &l, &row).unwrap(), Value::Null);
        assert_eq!(eval_expr("a % 4", &l, &row).unwrap(), Value::Int(2));
        assert_eq!(eval_expr("NULL + 1", &l, &row).unwrap(), Value::Null);
        assert_eq!(eval_expr("-a", &l, &row).unwrap(), Value::Int(-6));
    }

    #[test]
    fn comparisons_and_3vl() {
        let l = layout();
        let row = [Value::Int(6), Value::Null, Value::str("READY")];
        assert_eq!(eval_expr("a > 5", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("b > 5", &l, &row).unwrap(), Value::Null);
        // false AND null = false; true OR null = true
        assert_eq!(eval_expr("a < 5 AND b > 5", &l, &row).unwrap(), Value::Bool(false));
        assert_eq!(eval_expr("a > 5 OR b > 5", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("a > 5 AND b > 5", &l, &row).unwrap(), Value::Null);
        assert_eq!(eval_expr("NOT (a > 5)", &l, &row).unwrap(), Value::Bool(false));
    }

    #[test]
    fn predicates() {
        let l = layout();
        let row = [Value::Int(3), Value::Float(2.0), Value::str("READY")];
        assert_eq!(eval_expr("a IN (1, 3, 5)", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("a NOT IN (1, 3)", &l, &row).unwrap(), Value::Bool(false));
        assert_eq!(eval_expr("a BETWEEN 1 AND 5", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("a NOT BETWEEN 4 AND 5", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("s LIKE 'REA%'", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("s LIKE 'R_A%'", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("s NOT LIKE '%Z'", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("b IS NULL", &l, &row).unwrap(), Value::Bool(false));
        assert_eq!(eval_expr("b IS NOT NULL", &l, &row).unwrap(), Value::Bool(true));
    }

    #[test]
    fn like_escapes_regex_metachars() {
        let l = layout();
        let row = [Value::Int(0), Value::Float(0.0), Value::str("a.b(c)")];
        assert_eq!(eval_expr("s LIKE 'a.b(c)'", &l, &row).unwrap(), Value::Bool(true));
        assert_eq!(eval_expr("s LIKE 'axb(c)'", &l, &row).unwrap(), Value::Bool(false));
    }

    #[test]
    fn functions() {
        let l = layout();
        let row = [Value::Int(-3), Value::Null, Value::str("Ready")];
        assert_eq!(eval_expr("NOW()", &l, &row).unwrap(), Value::Float(1000.0));
        assert_eq!(eval_expr("ABS(a)", &l, &row).unwrap(), Value::Int(3));
        assert_eq!(eval_expr("COALESCE(b, a, 9)", &l, &row).unwrap(), Value::Int(-3));
        assert_eq!(eval_expr("LENGTH(s)", &l, &row).unwrap(), Value::Int(5));
        assert_eq!(eval_expr("UPPER(s)", &l, &row).unwrap(), Value::str("READY"));
        assert_eq!(eval_expr("ROUND(2.567, 1)", &l, &row).unwrap(), Value::Float(2.6));
        assert_eq!(eval_expr("SQRT(9)", &l, &row).unwrap(), Value::Float(3.0));
        assert_eq!(
            eval_expr("CONCAT('x=', a)", &l, &row).unwrap(),
            Value::str("x=-3")
        );
    }

    #[test]
    fn case_expr_eval() {
        let l = layout();
        let row = [Value::Int(0), Value::Float(0.0), Value::str("x")];
        assert_eq!(
            eval_expr("CASE WHEN a > 0 THEN 'p' WHEN a < 0 THEN 'n' ELSE 'z' END", &l, &row)
                .unwrap(),
            Value::str("z")
        );
    }

    #[test]
    fn resolution_errors() {
        let l = Layout::new(vec![
            (Some("a".into()), "x".into()),
            (Some("b".into()), "x".into()),
        ]);
        // unqualified 'x' is ambiguous
        assert!(l.resolve(None, "x").is_err());
        assert_eq!(l.resolve(Some("a"), "x").unwrap(), 0);
        assert_eq!(l.resolve(Some("b"), "x").unwrap(), 1);
        assert!(l.resolve(Some("c"), "x").is_err());
        assert!(l.resolve(None, "nope").is_err());
    }

    #[test]
    fn unbound_parameter_is_a_clear_error() {
        let l = layout();
        let e = Expr::Binary(
            Op::Eq,
            Box::new(Expr::Col { table: None, name: "a".into() }),
            Box::new(Expr::Param(0)),
        );
        let err = bind(&e, &l).unwrap_err();
        assert!(err.to_string().contains("unbound parameter"), "{err}");
    }

    #[test]
    fn where_matches_treats_null_as_false() {
        let l = layout();
        let row = [Value::Int(1), Value::Null, Value::str("x")];
        let sql = parse("SELECT * FROM t WHERE b > 0").unwrap();
        let w = match sql {
            Statement::Select(s) => s.where_.unwrap(),
            _ => panic!(),
        };
        let b = bind(&w, &l).unwrap();
        assert!(!b.matches(&row, &ctx()).unwrap());
    }
}
