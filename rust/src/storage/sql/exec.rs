//! SELECT pipeline: join → filter → group/aggregate → having → order →
//! project → limit.
//!
//! The cluster layer is responsible for *getting rows out of partitions*
//! (pruning, index probes, replica choice, locking); this module implements
//! the relational algebra over materialized row streams. Steering queries
//! (Table 2 of the paper) exercise every stage.

use super::ast::*;
use super::expr::{bind, Bound, EvalCtx, Layout};
use crate::storage::value::{Row, Value};
use crate::storage::ResultSet;
use crate::{Error, Result};
use rustc_hash::FxHashMap;

/// Materialized input relation for one table reference.
pub struct TableInput {
    /// Binding name (alias or table name) qualifying its columns.
    pub binding: String,
    /// Column names (unqualified).
    pub columns: Vec<String>,
    pub rows: Vec<Row>,
}

impl TableInput {
    pub fn layout(&self) -> Layout {
        Layout::of_table(&self.binding, self.columns.iter().cloned())
    }
}

/// Run a SELECT over the supplied inputs. `inputs[0]` is the FROM table;
/// `inputs[1..]` line up with `stmt.joins`.
pub fn run_select(stmt: &SelectStmt, inputs: Vec<TableInput>, ctx: &EvalCtx) -> Result<ResultSet> {
    if inputs.len() != stmt.joins.len() + 1 {
        return Err(Error::Engine(format!(
            "select needs {} inputs, got {}",
            stmt.joins.len() + 1,
            inputs.len()
        )));
    }

    // 1. joins
    let mut layout = inputs[0].layout();
    let mut rows: Vec<Row> = inputs[0].rows.clone();
    for (join, input) in stmt.joins.iter().zip(inputs[1..].iter()) {
        let right_layout = input.layout();
        let (next_rows, next_layout) =
            join_rows(&rows, &layout, &input.rows, &right_layout, join, ctx)?;
        rows = next_rows;
        layout = next_layout;
    }

    // 2. WHERE
    if let Some(w) = &stmt.where_ {
        let b = bind(w, &layout)?;
        let mut kept = Vec::with_capacity(rows.len());
        for r in rows {
            if b.matches(&r.values, ctx)? {
                kept.push(r);
            }
        }
        rows = kept;
    }

    // 3. alias substitution: ORDER BY / HAVING may reference select aliases.
    let aliases: Vec<(String, Expr)> = stmt
        .items
        .iter()
        .filter_map(|it| match it {
            SelectItem::Expr { expr, alias: Some(a) } => Some((a.clone(), expr.clone())),
            _ => None,
        })
        .collect();
    let subst = |e: &Expr| substitute_aliases(e, &aliases);
    let having = stmt.having.as_ref().map(&subst);
    let order_by: Vec<(Expr, bool)> =
        stmt.order_by.iter().map(|(e, asc)| (subst(e), *asc)).collect();
    // MySQL-style: GROUP BY may reference select aliases too
    let group_by: Vec<Expr> = stmt.group_by.iter().map(&subst).collect();
    let items: Vec<SelectItem> = stmt
        .items
        .iter()
        .map(|it| match it {
            SelectItem::Expr { expr, alias } => {
                SelectItem::Expr { expr: expr.clone(), alias: alias.clone() }
            }
            w => w.clone(),
        })
        .collect();

    // 4. aggregation
    let needs_agg = !stmt.group_by.is_empty()
        || items.iter().any(|it| matches!(it, SelectItem::Expr { expr, .. } if expr.has_aggregate()))
        || having.as_ref().map_or(false, |e| e.has_aggregate())
        || order_by.iter().any(|(e, _)| e.has_aggregate());

    let (rows, layout, items, having, order_by) = if needs_agg {
        aggregate(rows, layout, &group_by, items, having, order_by, ctx)?
    } else {
        (rows, layout, items, having, order_by)
    };

    // 5.–8. HAVING / ORDER BY / LIMIT / projection (shared with the
    // scatter-gather merge stage, so both paths finish identically).
    finish_select(rows, &layout, &items, having.as_ref(), &order_by, stmt.limit, ctx)
}

/// Pipeline stages 5–8 — HAVING filter, ORDER BY, LIMIT, projection — over
/// an already joined/filtered/aggregated row stream. `having`/`order_by`
/// must already have aggregates rewritten to `#.aggN` references when
/// `layout` is an aggregate output layout. Shared by [`run_select`] and the
/// scatter-gather engine's coordinator merge (`crate::query`), which is
/// what guarantees the two paths produce identical results.
pub fn finish_select(
    rows: Vec<Row>,
    layout: &Layout,
    items: &[SelectItem],
    having: Option<&Expr>,
    order_by: &[(Expr, bool)],
    limit: Option<u64>,
    ctx: &EvalCtx,
) -> Result<ResultSet> {
    // HAVING (after aggregation; without aggregation it acts as a second
    // WHERE, matching MySQL's permissiveness)
    let mut rows = rows;
    if let Some(h) = having {
        let b = bind(h, layout)?;
        let mut kept = Vec::with_capacity(rows.len());
        for r in rows {
            if b.matches(&r.values, ctx)? {
                kept.push(r);
            }
        }
        rows = kept;
    }

    // ORDER BY
    if !order_by.is_empty() {
        let keys: Vec<(Bound, bool)> = order_by
            .iter()
            .map(|(e, asc)| Ok((bind(e, layout)?, *asc)))
            .collect::<Result<Vec<_>>>()?;
        let mut decorated: Vec<(Vec<Value>, Row)> = rows
            .into_iter()
            .map(|r| {
                let k = keys
                    .iter()
                    .map(|(b, _)| b.eval(&r.values, ctx))
                    .collect::<Result<Vec<_>>>()?;
                Ok((k, r))
            })
            .collect::<Result<Vec<_>>>()?;
        decorated.sort_by(|(ka, _), (kb, _)| {
            for ((a, b), (_, asc)) in ka.iter().zip(kb.iter()).zip(keys.iter()) {
                let o = a.total_cmp(b);
                let o = if *asc { o } else { o.reverse() };
                if o != std::cmp::Ordering::Equal {
                    return o;
                }
            }
            std::cmp::Ordering::Equal
        });
        rows = decorated.into_iter().map(|(_, r)| r).collect();
    }

    // LIMIT
    if let Some(n) = limit {
        rows.truncate(n as usize);
    }

    // projection
    project(items, layout, rows, ctx)
}

/// Substitute bare column refs that name a select alias with the aliased
/// expression (SQL's ORDER BY/HAVING alias visibility). Public because the
/// scatter-gather planner performs the same rewrite when splitting a SELECT
/// into partial and merge plans.
pub fn substitute_aliases(e: &Expr, aliases: &[(String, Expr)]) -> Expr {
    match e {
        Expr::Col { table: None, name } => {
            for (a, ex) in aliases {
                if a.eq_ignore_ascii_case(name) {
                    return ex.clone();
                }
            }
            e.clone()
        }
        Expr::Unary(op, x) => Expr::Unary(*op, Box::new(substitute_aliases(x, aliases))),
        Expr::Binary(op, a, b) => Expr::Binary(
            *op,
            Box::new(substitute_aliases(a, aliases)),
            Box::new(substitute_aliases(b, aliases)),
        ),
        Expr::Func { name, args } => Expr::Func {
            name: name.clone(),
            args: args.iter().map(|a| substitute_aliases(a, aliases)).collect(),
        },
        Expr::InList { expr, list, negated } => Expr::InList {
            expr: Box::new(substitute_aliases(expr, aliases)),
            list: list.iter().map(|a| substitute_aliases(a, aliases)).collect(),
            negated: *negated,
        },
        Expr::Between { expr, lo, hi, negated } => Expr::Between {
            expr: Box::new(substitute_aliases(expr, aliases)),
            lo: Box::new(substitute_aliases(lo, aliases)),
            hi: Box::new(substitute_aliases(hi, aliases)),
            negated: *negated,
        },
        Expr::IsNull { expr, negated } => Expr::IsNull {
            expr: Box::new(substitute_aliases(expr, aliases)),
            negated: *negated,
        },
        Expr::Like { expr, pattern, negated } => Expr::Like {
            expr: Box::new(substitute_aliases(expr, aliases)),
            pattern: pattern.clone(),
            negated: *negated,
        },
        Expr::Case { arms, else_ } => Expr::Case {
            arms: arms
                .iter()
                .map(|(c, v)| (substitute_aliases(c, aliases), substitute_aliases(v, aliases)))
                .collect(),
            else_: else_.as_ref().map(|x| Box::new(substitute_aliases(x, aliases))),
        },
        other => other.clone(),
    }
}

// ---------------- joins ----------------

fn join_rows(
    left: &[Row],
    left_layout: &Layout,
    right: &[Row],
    right_layout: &Layout,
    join: &Join,
    ctx: &EvalCtx,
) -> Result<(Vec<Row>, Layout)> {
    let out_layout = left_layout.join(right_layout);
    // Equi-join detection: ON a.x = b.y with one side in each layout.
    let equi = match &join.on {
        Expr::Binary(Op::Eq, a, b) => {
            let try_pair = |x: &Expr, y: &Expr| -> Option<(usize, usize)> {
                if let (Expr::Col { table: tx, name: nx }, Expr::Col { table: ty, name: ny }) =
                    (x, y)
                {
                    let li = left_layout.resolve(tx.as_deref(), nx).ok()?;
                    let ri = right_layout.resolve(ty.as_deref(), ny).ok()?;
                    Some((li, ri))
                } else {
                    None
                }
            };
            try_pair(a, b).or_else(|| try_pair(b, a).map(|(l, r)| (l, r)))
        }
        _ => None,
    };

    let mut out = Vec::new();
    if let Some((li, ri)) = equi {
        // hash join on the right side
        let mut table: FxHashMap<u64, Vec<usize>> = FxHashMap::default();
        for (i, r) in right.iter().enumerate() {
            let v = &r.values[ri];
            if !v.is_null() {
                table.entry(v.hash_key()).or_default().push(i);
            }
        }
        for l in left {
            let lv = &l.values[li];
            let mut matched = false;
            if !lv.is_null() {
                if let Some(cands) = table.get(&lv.hash_key()) {
                    for &i in cands {
                        // re-check equality (hash collisions)
                        if lv.sql_eq(&right[i].values[ri]) == Some(true) {
                            let mut vals = l.values.clone();
                            vals.extend(right[i].values.iter().cloned());
                            out.push(Row::new(vals));
                            matched = true;
                        }
                    }
                }
            }
            if !matched && join.left_outer {
                let mut vals = l.values.clone();
                vals.extend(std::iter::repeat(Value::Null).take(right_layout.len()));
                out.push(Row::new(vals));
            }
        }
    } else {
        // general nested-loop join on the bound ON expression
        let b = bind(&join.on, &out_layout)?;
        for l in left {
            let mut matched = false;
            for r in right {
                let mut vals = l.values.clone();
                vals.extend(r.values.iter().cloned());
                if b.matches(&vals, ctx)? {
                    out.push(Row::new(vals));
                    matched = true;
                }
            }
            if !matched && join.left_outer {
                let mut vals = l.values.clone();
                vals.extend(std::iter::repeat(Value::Null).take(right_layout.len()));
                out.push(Row::new(vals));
            }
        }
    }
    Ok((out, out_layout))
}

// ---------------- aggregation ----------------

/// Aggregate accumulator.
///
/// The state is *mergeable*: two accumulators for the same aggregate over
/// disjoint row sets combine losslessly via [`AggState::merge`], which is
/// the algebraic property the scatter-gather engine pushes down — every
/// partition computes a partial `AggState` per group, and the coordinator
/// merges partials instead of shipping rows:
///
/// | aggregate        | partial state      | merge                      |
/// |------------------|--------------------|----------------------------|
/// | COUNT            | count              | add counts                 |
/// | SUM / AVG        | sum, count, is-int | add sums and counts        |
/// | MIN / MAX        | extremum           | take extremum of extrema   |
/// | any DISTINCT agg | value set          | union sets, re-accumulate  |
pub struct AggState {
    func: AggFunc,
    distinct: bool,
    count: u64,
    sum: f64,
    all_int: bool,
    min: Option<Value>,
    max: Option<Value>,
    seen: FxHashMap<u64, Vec<Value>>,
}

impl AggState {
    pub fn new(func: AggFunc, distinct: bool) -> AggState {
        AggState {
            func,
            distinct,
            count: 0,
            sum: 0.0,
            all_int: true,
            min: None,
            max: None,
            seen: FxHashMap::default(),
        }
    }

    /// Fold one input value into the accumulator. `v = None` means
    /// `COUNT(*)` (count the row unconditionally).
    pub fn push(&mut self, v: Option<Value>) -> Result<()> {
        // v = None means COUNT(*) (count the row unconditionally)
        let Some(v) = v else {
            self.count += 1;
            return Ok(());
        };
        if v.is_null() {
            return Ok(()); // aggregates skip NULLs
        }
        if self.distinct {
            let bucket = self.seen.entry(v.hash_key()).or_default();
            if bucket.iter().any(|x| x.sql_eq(&v) == Some(true)) {
                return Ok(());
            }
            bucket.push(v.clone());
        }
        self.count += 1;
        match self.func {
            AggFunc::Count => {}
            AggFunc::Sum | AggFunc::Avg => {
                let f = v
                    .as_f64()
                    .ok_or_else(|| Error::Type(format!("{} on non-numeric {v}", self.func.name())))?;
                self.sum += f;
                if !matches!(v, Value::Int(_)) {
                    self.all_int = false;
                }
            }
            AggFunc::Min => {
                if self.min.as_ref().map_or(true, |m| v.sql_cmp(m) == Some(std::cmp::Ordering::Less))
                {
                    self.min = Some(v);
                }
            }
            AggFunc::Max => {
                if self
                    .max
                    .as_ref()
                    .map_or(true, |m| v.sql_cmp(m) == Some(std::cmp::Ordering::Greater))
                {
                    self.max = Some(v);
                }
            }
        }
        Ok(())
    }

    /// Merge another partial accumulator for the *same* aggregate spec into
    /// this one. Non-distinct states combine algebraically; DISTINCT states
    /// re-push the other side's value set so dedup and re-accumulation stay
    /// consistent with the single-pass path.
    pub fn merge(&mut self, other: AggState) -> Result<()> {
        if self.distinct {
            for vals in other.seen.into_values() {
                for v in vals {
                    self.push(Some(v))?;
                }
            }
            return Ok(());
        }
        self.count += other.count;
        self.sum += other.sum;
        self.all_int &= other.all_int;
        if let Some(v) = other.min {
            if self
                .min
                .as_ref()
                .map_or(true, |m| v.sql_cmp(m) == Some(std::cmp::Ordering::Less))
            {
                self.min = Some(v);
            }
        }
        if let Some(v) = other.max {
            if self
                .max
                .as_ref()
                .map_or(true, |m| v.sql_cmp(m) == Some(std::cmp::Ordering::Greater))
            {
                self.max = Some(v);
            }
        }
        Ok(())
    }

    /// Final value of the accumulated aggregate.
    pub fn finish(&self) -> Value {
        match self.func {
            AggFunc::Count => Value::Int(self.count as i64),
            AggFunc::Sum => {
                if self.count == 0 {
                    Value::Null
                } else if self.all_int && self.sum.abs() < 9e15 {
                    Value::Int(self.sum as i64)
                } else {
                    Value::Float(self.sum)
                }
            }
            AggFunc::Avg => {
                if self.count == 0 {
                    Value::Null
                } else {
                    Value::Float(self.sum / self.count as f64)
                }
            }
            AggFunc::Min => self.min.clone().unwrap_or(Value::Null),
            AggFunc::Max => self.max.clone().unwrap_or(Value::Null),
        }
    }
}

/// Rewrite aggregate calls in an expression into references to synthetic
/// columns `#.aggN`, registering each distinct aggregate in `aggs`. Public
/// because the scatter-gather planner performs the same rewrite: the agg
/// list becomes the pushed-down partial plan, the rewritten expressions
/// become the coordinator merge plan.
pub fn rewrite_aggregates(e: &Expr, aggs: &mut Vec<Expr>) -> Expr {
    match e {
        Expr::Agg { .. } => {
            let idx = match aggs.iter().position(|a| a == e) {
                Some(i) => i,
                None => {
                    aggs.push(e.clone());
                    aggs.len() - 1
                }
            };
            Expr::Col { table: Some("#".into()), name: format!("agg{idx}") }
        }
        Expr::Unary(op, x) => Expr::Unary(*op, Box::new(rewrite_aggregates(x, aggs))),
        Expr::Binary(op, a, b) => Expr::Binary(
            *op,
            Box::new(rewrite_aggregates(a, aggs)),
            Box::new(rewrite_aggregates(b, aggs)),
        ),
        Expr::Func { name, args } => Expr::Func {
            name: name.clone(),
            args: args.iter().map(|a| rewrite_aggregates(a, aggs)).collect(),
        },
        Expr::InList { expr, list, negated } => Expr::InList {
            expr: Box::new(rewrite_aggregates(expr, aggs)),
            list: list.iter().map(|a| rewrite_aggregates(a, aggs)).collect(),
            negated: *negated,
        },
        Expr::Between { expr, lo, hi, negated } => Expr::Between {
            expr: Box::new(rewrite_aggregates(expr, aggs)),
            lo: Box::new(rewrite_aggregates(lo, aggs)),
            hi: Box::new(rewrite_aggregates(hi, aggs)),
            negated: *negated,
        },
        Expr::IsNull { expr, negated } => Expr::IsNull {
            expr: Box::new(rewrite_aggregates(expr, aggs)),
            negated: *negated,
        },
        Expr::Like { expr, pattern, negated } => Expr::Like {
            expr: Box::new(rewrite_aggregates(expr, aggs)),
            pattern: pattern.clone(),
            negated: *negated,
        },
        Expr::Case { arms, else_ } => Expr::Case {
            arms: arms
                .iter()
                .map(|(c, v)| (rewrite_aggregates(c, aggs), rewrite_aggregates(v, aggs)))
                .collect(),
            else_: else_.as_ref().map(|x| Box::new(rewrite_aggregates(x, aggs))),
        },
        other => other.clone(),
    }
}

type AggOut = (Vec<Row>, Layout, Vec<SelectItem>, Option<Expr>, Vec<(Expr, bool)>);

/// Group rows, compute aggregates, and rewrite items/having/order to refer
/// to the extended layout (base columns of a representative row + one
/// synthetic column per aggregate).
fn aggregate(
    rows: Vec<Row>,
    layout: Layout,
    group_by: &[Expr],
    items: Vec<SelectItem>,
    having: Option<Expr>,
    order_by: Vec<(Expr, bool)>,
    ctx: &EvalCtx,
) -> Result<AggOut> {
    let mut aggs: Vec<Expr> = Vec::new();
    let items: Vec<SelectItem> = items
        .into_iter()
        .map(|it| match it {
            SelectItem::Expr { expr, alias } => {
                SelectItem::Expr { expr: rewrite_aggregates(&expr, &mut aggs), alias }
            }
            w => w,
        })
        .collect();
    let having = having.map(|h| rewrite_aggregates(&h, &mut aggs));
    let order_by: Vec<(Expr, bool)> = order_by
        .into_iter()
        .map(|(e, asc)| (rewrite_aggregates(&e, &mut aggs), asc))
        .collect();

    // Bind group keys and aggregate arguments against the base layout.
    let key_bound: Vec<Bound> =
        group_by.iter().map(|e| bind(e, &layout)).collect::<Result<Vec<_>>>()?;
    struct AggSpec {
        func: AggFunc,
        distinct: bool,
        arg: Option<Bound>,
    }
    let agg_specs: Vec<AggSpec> = aggs
        .iter()
        .map(|a| match a {
            Expr::Agg { func, arg, distinct } => Ok(AggSpec {
                func: *func,
                distinct: *distinct,
                arg: match arg {
                    Some(e) => Some(bind(e, &layout)?),
                    None => None,
                },
            }),
            _ => unreachable!("aggs only collects Agg nodes"),
        })
        .collect::<Result<Vec<_>>>()?;

    // Group. Key identity uses the rendered total-order form of the values.
    let mut groups: FxHashMap<Vec<u64>, (Row, Vec<AggState>)> = FxHashMap::default();
    let mut order: Vec<Vec<u64>> = Vec::new(); // first-seen group order
    for r in rows {
        let key: Vec<u64> = key_bound
            .iter()
            .map(|b| Ok(b.eval(&r.values, ctx)?.hash_key()))
            .collect::<Result<Vec<_>>>()?;
        let g = match groups.get_mut(&key) {
            Some(g) => g,
            None => {
                order.push(key.clone());
                groups.entry(key).or_insert_with(|| {
                    (
                        r.clone(),
                        agg_specs
                            .iter()
                            .map(|s| AggState::new(s.func, s.distinct))
                            .collect(),
                    )
                })
            }
        };
        for (st, spec) in g.1.iter_mut().zip(&agg_specs) {
            let v = match &spec.arg {
                Some(b) => Some(b.eval(&r.values, ctx)?),
                None => None,
            };
            st.push(v)?;
        }
    }
    let spec_pairs: Vec<(AggFunc, bool)> =
        agg_specs.iter().map(|s| (s.func, s.distinct)).collect();
    let (out_rows, ext) = finish_groups(order, groups, &spec_pairs, &layout, group_by.is_empty());
    Ok((out_rows, ext, items, having, order_by))
}

/// Grouped-aggregation epilogue shared by the centralized pipeline and the
/// scatter-gather coordinator merge: synthesize the single all-NULL global
/// group when a `GROUP BY`-less aggregate saw no input, extend the layout
/// with one synthetic `#.aggN` column per aggregate, and emit one row per
/// group (representative values + finished aggregates) in first-seen order.
/// Keeping this in one place is what keeps the two paths' aggregate output
/// layouts identical by construction.
pub fn finish_groups(
    order: Vec<Vec<u64>>,
    groups: FxHashMap<Vec<u64>, (Row, Vec<AggState>)>,
    agg_specs: &[(AggFunc, bool)],
    layout: &Layout,
    group_by_is_empty: bool,
) -> (Vec<Row>, Layout) {
    let mut order = order;
    let mut groups = groups;
    // Global aggregate over empty input still yields one group.
    if groups.is_empty() && group_by_is_empty {
        let key: Vec<u64> = vec![];
        order.push(key.clone());
        groups.insert(
            key,
            (
                Row::new(vec![Value::Null; layout.len()]),
                agg_specs.iter().map(|(f, d)| AggState::new(*f, *d)).collect(),
            ),
        );
    }
    // Extended layout: base columns + synthetic "#.aggN".
    let mut ext = layout.clone();
    for i in 0..agg_specs.len() {
        ext.cols.push((Some("#".into()), format!("agg{i}")));
    }
    let mut out_rows = Vec::with_capacity(order.len());
    for key in order {
        let (rep, states) = groups.remove(&key).expect("ordered group present");
        let mut vals = rep.values;
        vals.extend(states.iter().map(|s| s.finish()));
        out_rows.push(Row::new(vals));
    }
    (out_rows, ext)
}

// ---------------- projection ----------------

fn project(
    items: &[SelectItem],
    layout: &Layout,
    rows: Vec<Row>,
    ctx: &EvalCtx,
) -> Result<ResultSet> {
    // Build (output name, bound expr or passthrough index) list.
    enum Out {
        Col(usize),
        Expr(Bound),
    }
    let mut names = Vec::new();
    let mut outs = Vec::new();
    for (i, it) in items.iter().enumerate() {
        match it {
            SelectItem::Wildcard(qual) => {
                for (ci, (q, n)) in layout.cols.iter().enumerate() {
                    // hide synthetic aggregate columns from `*`
                    if q.as_deref() == Some("#") {
                        continue;
                    }
                    let include = match qual {
                        None => true,
                        Some(t) => q.as_deref().map_or(false, |x| x.eq_ignore_ascii_case(t)),
                    };
                    if include {
                        names.push(n.clone());
                        outs.push(Out::Col(ci));
                    }
                }
                if let Some(t) = qual {
                    if !layout
                        .cols
                        .iter()
                        .any(|(q, _)| q.as_deref().map_or(false, |x| x.eq_ignore_ascii_case(t)))
                    {
                        return Err(Error::Type(format!("unknown table '{t}' in {t}.*")));
                    }
                }
            }
            SelectItem::Expr { expr, alias } => {
                let name = alias.clone().unwrap_or_else(|| default_name(expr, i));
                names.push(name);
                outs.push(Out::Expr(bind(expr, layout)?));
            }
        }
    }
    let mut out_rows = Vec::with_capacity(rows.len());
    for r in rows {
        let mut vals = Vec::with_capacity(outs.len());
        for o in &outs {
            vals.push(match o {
                Out::Col(i) => r.values[*i].clone(),
                Out::Expr(b) => b.eval(&r.values, ctx)?,
            });
        }
        out_rows.push(Row::new(vals));
    }
    Ok(ResultSet { columns: names, rows: out_rows })
}

/// Output column name for an unaliased item.
fn default_name(e: &Expr, idx: usize) -> String {
    match e {
        Expr::Col { name, .. } => name.clone(),
        Expr::Func { name, .. } => name.to_lowercase(),
        // rewritten aggregates keep a stable name via their position
        _ => format!("expr{idx}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::sql::parse;
    use crate::storage::sql::Statement;

    fn ctx() -> EvalCtx {
        EvalCtx { now: 100.0 }
    }

    fn tasks_input(binding: &str) -> TableInput {
        // taskid, wid, status, dur
        let mk = |id: i64, w: i64, st: &str, d: f64| {
            Row::new(vec![Value::Int(id), Value::Int(w), Value::str(st), Value::Float(d)])
        };
        TableInput {
            binding: binding.into(),
            columns: vec!["taskid".into(), "wid".into(), "status".into(), "dur".into()],
            rows: vec![
                mk(1, 0, "FINISHED", 10.0),
                mk(2, 0, "RUNNING", 5.0),
                mk(3, 1, "FINISHED", 20.0),
                mk(4, 1, "FINISHED", 30.0),
                mk(5, 2, "READY", 0.0),
            ],
        }
    }

    fn workers_input() -> TableInput {
        let mk = |id: i64, host: &str| Row::new(vec![Value::Int(id), Value::str(host)]);
        TableInput {
            binding: "w".into(),
            columns: vec!["id".into(), "host".into()],
            rows: vec![mk(0, "n0"), mk(1, "n1"), mk(3, "n3")],
        }
    }

    fn select(sql: &str) -> SelectStmt {
        match parse(sql).unwrap() {
            Statement::Select(s) => s,
            _ => panic!(),
        }
    }

    fn run(sql: &str, inputs: Vec<TableInput>) -> ResultSet {
        run_select(&select(sql), inputs, &ctx()).unwrap()
    }

    #[test]
    fn filter_order_limit_project() {
        let rs = run(
            "SELECT taskid, dur FROM t WHERE status = 'FINISHED' ORDER BY dur DESC LIMIT 2",
            vec![tasks_input("t")],
        );
        assert_eq!(rs.columns, vec!["taskid", "dur"]);
        assert_eq!(rs.rows.len(), 2);
        assert_eq!(rs.rows[0].values[0], Value::Int(4));
        assert_eq!(rs.rows[1].values[0], Value::Int(3));
    }

    #[test]
    fn wildcard_and_qualified_wildcard() {
        let rs = run("SELECT * FROM t LIMIT 1", vec![tasks_input("t")]);
        assert_eq!(rs.columns.len(), 4);
        let rs = run(
            "SELECT t.* FROM t JOIN w ON t.wid = w.id LIMIT 1",
            vec![tasks_input("t"), workers_input()],
        );
        assert_eq!(rs.columns.len(), 4);
    }

    #[test]
    fn group_by_with_aggregates_and_having() {
        let rs = run(
            "SELECT wid, COUNT(*) AS n, AVG(dur) a, MAX(dur), MIN(dur), SUM(taskid) \
             FROM t WHERE status = 'FINISHED' GROUP BY wid HAVING n >= 1 ORDER BY wid",
            vec![tasks_input("t")],
        );
        assert_eq!(rs.rows.len(), 2);
        // wid 0: one finished task (id 1, dur 10)
        assert_eq!(rs.rows[0].values[0], Value::Int(0));
        assert_eq!(rs.rows[0].values[1], Value::Int(1));
        assert_eq!(rs.rows[0].values[2], Value::Float(10.0));
        // wid 1: two finished (dur 20,30; ids 3,4)
        assert_eq!(rs.rows[1].values[1], Value::Int(2));
        assert_eq!(rs.rows[1].values[2], Value::Float(25.0));
        assert_eq!(rs.rows[1].values[3], Value::Float(30.0));
        assert_eq!(rs.rows[1].values[4], Value::Float(20.0));
        assert_eq!(rs.rows[1].values[5], Value::Int(7));
    }

    #[test]
    fn having_filters_groups() {
        let rs = run(
            "SELECT wid, COUNT(*) n FROM t GROUP BY wid HAVING COUNT(*) > 1 ORDER BY wid",
            vec![tasks_input("t")],
        );
        assert_eq!(rs.rows.len(), 2); // wid 0 and 1 have 2 tasks each
    }

    #[test]
    fn global_aggregate_without_group_by() {
        let rs = run("SELECT COUNT(*), AVG(dur) FROM t", vec![tasks_input("t")]);
        assert_eq!(rs.rows.len(), 1);
        assert_eq!(rs.rows[0].values[0], Value::Int(5));
        assert_eq!(rs.rows[0].values[1], Value::Float(13.0));
    }

    #[test]
    fn global_aggregate_over_empty_input() {
        let mut t = tasks_input("t");
        t.rows.clear();
        let rs = run("SELECT COUNT(*), SUM(dur), MIN(dur) FROM t", vec![t]);
        assert_eq!(rs.rows.len(), 1);
        assert_eq!(rs.rows[0].values[0], Value::Int(0));
        assert_eq!(rs.rows[0].values[1], Value::Null);
        assert_eq!(rs.rows[0].values[2], Value::Null);
    }

    #[test]
    fn count_distinct() {
        let rs = run("SELECT COUNT(DISTINCT status) FROM t", vec![tasks_input("t")]);
        assert_eq!(rs.rows[0].values[0], Value::Int(3));
    }

    #[test]
    fn inner_join_hash_path() {
        let rs = run(
            "SELECT t.taskid, w.host FROM t JOIN w ON t.wid = w.id ORDER BY t.taskid",
            vec![tasks_input("t"), workers_input()],
        );
        // wid=2 task has no worker row -> excluded
        assert_eq!(rs.rows.len(), 4);
        assert_eq!(rs.rows[0].values[1], Value::str("n0"));
    }

    #[test]
    fn left_join_pads_nulls() {
        let rs = run(
            "SELECT t.taskid, w.host FROM t LEFT JOIN w ON t.wid = w.id ORDER BY t.taskid",
            vec![tasks_input("t"), workers_input()],
        );
        assert_eq!(rs.rows.len(), 5);
        assert_eq!(rs.rows[4].values[1], Value::Null); // wid=2 unmatched
    }

    #[test]
    fn nested_loop_join_on_inequality() {
        let rs = run(
            "SELECT COUNT(*) FROM t JOIN w ON t.wid < w.id",
            vec![tasks_input("t"), workers_input()],
        );
        // pairs with wid < id: wid0 x {1,3}=2 rows*2 tasks=4, wid1 x {3}=2, wid2 x {3}=1 → 7
        assert_eq!(rs.rows[0].values[0], Value::Int(7));
    }

    #[test]
    fn order_by_alias_and_aggregate() {
        let rs = run(
            "SELECT wid, COUNT(*) AS n FROM t GROUP BY wid ORDER BY n DESC, wid ASC",
            vec![tasks_input("t")],
        );
        assert_eq!(rs.rows[0].values[0], Value::Int(0)); // n=2, wid 0 before wid 1
        assert_eq!(rs.rows[2].values[0], Value::Int(2)); // n=1 last
    }

    #[test]
    fn expression_projection_with_now() {
        let rs = run(
            "SELECT taskid, NOW() - dur AS remaining FROM t WHERE taskid = 1",
            vec![tasks_input("t")],
        );
        assert_eq!(rs.columns[1], "remaining");
        assert_eq!(rs.rows[0].values[1], Value::Float(90.0));
    }

    #[test]
    fn arity_mismatch_is_engine_error() {
        let s = select("SELECT * FROM t JOIN w ON t.wid = w.id");
        assert!(run_select(&s, vec![tasks_input("t")], &ctx()).is_err());
    }

    #[test]
    fn agg_state_merge_matches_single_pass() {
        let vals: Vec<Value> = (0..20)
            .map(|i| if i % 5 == 0 { Value::Null } else { Value::Int(i % 7) })
            .collect();
        for (func, distinct) in [
            (AggFunc::Count, false),
            (AggFunc::Count, true),
            (AggFunc::Sum, false),
            (AggFunc::Sum, true),
            (AggFunc::Avg, false),
            (AggFunc::Avg, true),
            (AggFunc::Min, false),
            (AggFunc::Min, true),
            (AggFunc::Max, false),
            (AggFunc::Max, true),
        ] {
            let mut whole = AggState::new(func, distinct);
            for v in &vals {
                whole.push(Some(v.clone())).unwrap();
            }
            let mut left = AggState::new(func, distinct);
            let mut right = AggState::new(func, distinct);
            for (i, v) in vals.iter().enumerate() {
                let side = if i < 7 { &mut left } else { &mut right };
                side.push(Some(v.clone())).unwrap();
            }
            left.merge(right).unwrap();
            assert_eq!(
                left.finish(),
                whole.finish(),
                "merged partials diverge for {func:?} distinct={distinct}"
            );
        }
        // COUNT(*) partials (no argument) add row counts
        let mut a = AggState::new(AggFunc::Count, false);
        let mut b = AggState::new(AggFunc::Count, false);
        for _ in 0..3 {
            a.push(None).unwrap();
        }
        for _ in 0..4 {
            b.push(None).unwrap();
        }
        a.merge(b).unwrap();
        assert_eq!(a.finish(), Value::Int(7));
        // merging an empty partial is the identity
        let mut empty = AggState::new(AggFunc::Sum, false);
        let fresh = AggState::new(AggFunc::Sum, false);
        empty.push(Some(Value::Int(5))).unwrap();
        empty.merge(fresh).unwrap();
        assert_eq!(empty.finish(), Value::Int(5));
    }
}
