//! Data nodes: the processes that own partition replicas.
//!
//! In SchalaDB terminology (paper Figure 2), *data nodes* run the DBMS and
//! hold the distributed memory; *worker nodes* are clients. Here a data node
//! owns a set of partition replicas (primary or backup role is tracked by
//! the cluster catalog, not the node), a per-partition segmented redo WAL
//! ([`NodeWal`]), and a lifecycle state used by failure injection and the
//! availability machinery:
//!
//! ```text
//!        kill                restart_node              sweep (final cut)
//! Alive ------> Dead ------------------------> Rejoining ---------------> Alive
//!        revive (in-memory state intact: heal re-seeds stale replicas)
//! ```
//!
//! `revive` models a transient network partition (memory survives);
//! `restart_node` models a real process restart (memory wiped, state comes
//! back from checkpoints + WAL tails + primary catch-up).

use crate::obs::{span, Counter, Hist, ObsRegistry, PartMetric, Stage};
use crate::storage::partition::PartitionStore;
use crate::storage::table_def::TableDef;
use crate::storage::wal::{LogOp, NodeWal};
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::Instant;

/// Key of a partition replica within a node.
pub type PartKey = (String, usize);

/// Lifecycle state of a data node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    /// Serving reads/writes and receiving replica applies.
    Alive,
    /// Crashed / partitioned away; serves nothing.
    Dead,
    /// Restarted after a crash and catching up; serves nothing until the
    /// availability sweep's final cut flips it back to [`NodeState::Alive`].
    Rejoining,
    /// Freshly added to a running cluster (`DbCluster::add_node`). Hosts
    /// nothing yet and serves nothing; it is an eligible **rebalance
    /// target**, and the first completed partition hand-off onto it flips
    /// it to [`NodeState::Alive`].
    Joining,
}

const STATE_ALIVE: u8 = 0;
const STATE_DEAD: u8 = 1;
const STATE_REJOINING: u8 = 2;
const STATE_JOINING: u8 = 3;

/// One data node.
pub struct DataNode {
    pub id: u32,
    state: AtomicU8,
    /// Cluster epoch this node last joined under (stamped by the rejoin
    /// hand-off; replicas carry their own fence in `PartitionStore::epoch`).
    pub epoch: AtomicU64,
    /// Partition replicas hosted by this node. The outer lock only guards
    /// the map shape (DDL, replica placement); row access goes through the
    /// per-partition `RwLock`, which is the concurrency unit the paper's
    /// design leans on.
    parts: RwLock<FxHashMap<PartKey, Arc<RwLock<PartitionStore>>>>,
    /// Per-partition segmented redo log of committed ops on replicas
    /// hosted here (primary *and* backup — every replica can recover
    /// locally and serve a redo-ship tail).
    pub wal: Mutex<NodeWal>,
    /// Observability registry, attached once at cluster start. The node
    /// outlives WAL replacement (`attach_durability`, `restart_node`), so
    /// WAL metrics are recorded here rather than inside [`NodeWal`].
    obs: OnceLock<Arc<ObsRegistry>>,
}

impl DataNode {
    pub fn new(id: u32) -> DataNode {
        DataNode {
            id,
            state: AtomicU8::new(STATE_ALIVE),
            epoch: AtomicU64::new(0),
            parts: RwLock::new(FxHashMap::default()),
            wal: Mutex::new(NodeWal::new()),
            obs: OnceLock::new(),
        }
    }

    /// Share the cluster's observability registry with this node (called
    /// once at cluster start; later calls are no-ops).
    pub fn attach_obs(&self, obs: Arc<ObsRegistry>) {
        let _ = self.obs.set(obs);
    }

    /// Construct a node in the [`NodeState::Joining`] state (online node
    /// addition — see `DbCluster::add_node`).
    pub fn new_joining(id: u32) -> DataNode {
        let n = DataNode::new(id);
        n.state.store(STATE_JOINING, Ordering::SeqCst);
        n
    }

    /// Current lifecycle state.
    pub fn state(&self) -> NodeState {
        match self.state.load(Ordering::SeqCst) {
            STATE_ALIVE => NodeState::Alive,
            STATE_DEAD => NodeState::Dead,
            STATE_JOINING => NodeState::Joining,
            _ => NodeState::Rejoining,
        }
    }

    pub fn is_alive(&self) -> bool {
        self.state() == NodeState::Alive
    }

    /// Simulate a crash: the node stops serving. Its in-memory state is
    /// retained so tests can exercise both "network blip" (`revive`) and
    /// "process restart" (`DbCluster::restart_node`, which wipes it).
    pub fn kill(&self) {
        self.state.store(STATE_DEAD, Ordering::SeqCst);
    }

    /// Bring the node back with memory intact (after a transient outage;
    /// heal re-seeds whatever went stale).
    pub fn revive(&self) {
        self.state.store(STATE_ALIVE, Ordering::SeqCst);
    }

    /// Enter the rejoin state machine (wiped state, catching up).
    pub fn begin_rejoin(&self) {
        self.state.store(STATE_REJOINING, Ordering::SeqCst);
    }

    /// Rejoin hand-off: stamp the epoch the node caught up under and start
    /// serving again.
    pub fn finish_rejoin(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::SeqCst);
        self.state.store(STATE_ALIVE, Ordering::SeqCst);
    }

    /// Join hand-off: a freshly added node received its first partition
    /// through a completed rebalance cut and starts serving. Shares the
    /// epoch-stamp semantics of [`DataNode::finish_rejoin`].
    pub fn finish_join(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::SeqCst);
        self.state.store(STATE_ALIVE, Ordering::SeqCst);
    }

    /// Route durable logging under `dir` (one file per partition segment),
    /// flushing every `group_commit` commits. Called at cluster start and
    /// on restart, before any commit traffic reaches the node.
    pub fn attach_durability(&self, dir: PathBuf, group_commit: usize) {
        *self.wal.lock().unwrap() = NodeWal::with_dir(dir, group_commit);
    }

    fn check_alive(&self) -> Result<()> {
        if self.is_alive() {
            Ok(())
        } else {
            Err(Error::Unavailable(format!("data node {} is down", self.id)))
        }
    }

    /// Host a new (empty) replica of `def`'s partition `pidx`.
    pub fn host_partition(&self, def: Arc<TableDef>, pidx: usize) -> Result<()> {
        let mut g = self.parts.write().unwrap();
        let key = (def.name.clone(), pidx);
        if g.contains_key(&key) {
            return Err(Error::Catalog(format!(
                "node {} already hosts {}[{}]",
                self.id, key.0, key.1
            )));
        }
        g.insert(key, Arc::new(RwLock::new(PartitionStore::new(def))));
        Ok(())
    }

    /// Drop a hosted replica (re-replication source cleanup).
    pub fn drop_partition(&self, table: &str, pidx: usize) {
        self.parts.write().unwrap().remove(&(table.to_string(), pidx));
    }

    /// Handle to a hosted replica; errors if the node is down or does not
    /// host the replica.
    pub fn partition(&self, table: &str, pidx: usize) -> Result<Arc<RwLock<PartitionStore>>> {
        self.check_alive()?;
        self.partition_even_if_dead(table, pidx)
    }

    /// Same as [`DataNode::partition`] but usable on a dead or rejoining
    /// node (recovery path).
    pub fn partition_even_if_dead(
        &self,
        table: &str,
        pidx: usize,
    ) -> Result<Arc<RwLock<PartitionStore>>> {
        self.parts
            .read()
            .unwrap()
            .get(&(table.to_string(), pidx))
            .cloned()
            .ok_or_else(|| {
                Error::Unavailable(format!("node {} does not host {table}[{pidx}]", self.id))
            })
    }

    /// Whether a replica of `table[pidx]` lives here.
    pub fn hosts(&self, table: &str, pidx: usize) -> bool {
        self.parts.read().unwrap().contains_key(&(table.to_string(), pidx))
    }

    /// All replica keys hosted here.
    pub fn hosted_keys(&self) -> Vec<PartKey> {
        self.parts.read().unwrap().keys().cloned().collect()
    }

    /// Append one commit's redo records to the node WAL (both replica
    /// roles log; group commit batches the sink flush).
    pub fn log_commit(&self, epoch: u64, ops: &[(u64, LogOp)]) -> Result<()> {
        let obs = self.obs.get().filter(|o| o.is_enabled());
        let mut w = self.wal.lock().unwrap();
        let Some(o) = obs else {
            return w.commit(epoch, ops);
        };
        let pending_before = w.pending();
        let t0 = Instant::now();
        let r = w.commit(epoch, ops);
        let nanos = t0.elapsed().as_nanos() as u64;
        // commit() bumps pending by one, then flush_all() zeroes it when the
        // group-commit window fills — so "did not grow" means a flush ran.
        let flushed = w.pending() <= pending_before;
        drop(w);
        if r.is_ok() {
            o.addc(Counter::WalRecords, ops.len() as u64);
            for (_, op) in ops {
                o.part_add(PartMetric::WalRecords, op.pidx(), 1);
            }
            o.node_wal(self.id as usize, ops.len() as u64, flushed);
            if flushed {
                o.inc(Counter::WalFlushes);
                o.addc(Counter::WalFlushedCommits, (pending_before + 1) as u64);
                o.rec_nanos(Hist::WalFlush, nanos);
            }
        }
        span::stage_add(Stage::Wal, nanos);
        r
    }

    /// Apply a redo op to the local replica (replication / recovery).
    ///
    /// Slot-addressed: the WAL records the slot chosen by the primary, and
    /// the replica's slab must land the row in the same slot — enforced by
    /// `insert_at`, so replica divergence is caught immediately rather than
    /// silently.
    pub fn apply(&self, op: &LogOp) -> Result<()> {
        match op {
            LogOp::Insert { table, pidx, slot, row } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let mut p = part.write().unwrap();
                p.insert_at_arc(*slot, row.clone()).map_err(|e| {
                    Error::TxnAborted(format!(
                        "replica apply divergence on {table}[{pidx}]: {e}"
                    ))
                })
            }
            LogOp::Update { table, pidx, slot, row } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let r = part.write().unwrap().update_arc(*slot, row.clone()).map(|_| ());
                r
            }
            LogOp::Delete { table, pidx, slot } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let r = part.write().unwrap().delete(*slot).map(|_| ());
                r
            }
        }
    }

    /// Total resident bytes across hosted replicas.
    pub fn approx_bytes(&self) -> usize {
        let g = self.parts.read().unwrap();
        g.values().map(|p| p.read().unwrap().approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::{ColumnType, Row, Schema, Value};

    fn def() -> Arc<TableDef> {
        Arc::new(
            TableDef::new(
                "t",
                Schema::of(&[("id", ColumnType::Int), ("v", ColumnType::Float)]),
            )
            .with_primary_key("id")
            .unwrap(),
        )
    }

    #[test]
    fn host_and_access_partitions() {
        let n = DataNode::new(0);
        n.host_partition(def(), 0).unwrap();
        n.host_partition(def(), 1).unwrap();
        assert!(n.hosts("t", 0));
        assert!(!n.hosts("t", 2));
        assert!(n.partition("t", 0).is_ok());
        assert!(n.partition("t", 2).is_err());
        assert!(n.host_partition(def(), 0).is_err(), "double-host rejected");
        assert_eq!(n.hosted_keys().len(), 2);
    }

    #[test]
    fn state_machine_transitions() {
        let n = DataNode::new(3);
        assert_eq!(n.state(), NodeState::Alive);
        n.kill();
        assert_eq!(n.state(), NodeState::Dead);
        assert!(!n.is_alive());
        n.begin_rejoin();
        assert_eq!(n.state(), NodeState::Rejoining);
        assert!(!n.is_alive(), "a rejoining node must not serve");
        n.finish_rejoin(7);
        assert_eq!(n.state(), NodeState::Alive);
        assert_eq!(n.epoch.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn kill_blocks_access_but_preserves_state() {
        let n = DataNode::new(1);
        n.host_partition(def(), 0).unwrap();
        let p = n.partition("t", 0).unwrap();
        p.write()
            .unwrap()
            .insert(Row::new(vec![Value::Int(1), Value::Float(1.0)]))
            .unwrap();
        n.kill();
        assert!(!n.is_alive());
        assert!(n.partition("t", 0).is_err());
        // recovery path still reaches the data
        let p = n.partition_even_if_dead("t", 0).unwrap();
        assert_eq!(p.read().unwrap().len(), 1);
        n.revive();
        assert!(n.partition("t", 0).is_ok());
    }

    #[test]
    fn apply_replicates_ops_with_slot_check() {
        let primary = DataNode::new(0);
        let backup = DataNode::new(1);
        primary.host_partition(def(), 0).unwrap();
        backup.host_partition(def(), 0).unwrap();

        let row = Row::new(vec![Value::Int(7), Value::Float(3.0)]);
        let part = primary.partition("t", 0).unwrap();
        let slot = part.write().unwrap().insert(row.clone()).unwrap();
        let op = LogOp::Insert { table: "t".into(), pidx: 0, slot, row: Arc::new(row) };
        backup.apply(&op).unwrap();
        let bp = backup.partition("t", 0).unwrap();
        assert_eq!(bp.read().unwrap().len(), 1);

        // divergence detection: applying the same insert again must fail
        assert!(backup.apply(&op).is_err());
    }

    #[test]
    fn wal_commits_through_node() {
        let n = DataNode::new(0);
        n.log_commit(0, &[(1, LogOp::Delete { table: "t".into(), pidx: 0, slot: 3 })])
            .unwrap();
        let w = n.wal.lock().unwrap();
        assert_eq!(w.total_records(), 1);
        assert_eq!(w.segment("t", 0).unwrap().max_lsn(), 1);
    }
}
