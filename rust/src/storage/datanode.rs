//! Data nodes: the processes that own partition replicas.
//!
//! In SchalaDB terminology (paper Figure 2), *data nodes* run the DBMS and
//! hold the distributed memory; *worker nodes* are clients. Here a data node
//! owns a set of partition replicas (primary or backup role is tracked by
//! the cluster catalog, not the node), a redo WAL, and an `alive` flag used
//! by the failure-injection tests and the availability machinery.

use crate::storage::partition::PartitionStore;
use crate::storage::table_def::TableDef;
use crate::storage::wal::{LogOp, Wal};
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Key of a partition replica within a node.
pub type PartKey = (String, usize);

/// One data node.
pub struct DataNode {
    pub id: u32,
    alive: AtomicBool,
    /// Partition replicas hosted by this node. The outer lock only guards
    /// the map shape (DDL, replica placement); row access goes through the
    /// per-partition `RwLock`, which is the concurrency unit the paper's
    /// design leans on.
    parts: RwLock<FxHashMap<PartKey, Arc<RwLock<PartitionStore>>>>,
    /// Redo log of committed ops on primaries hosted here.
    pub wal: Mutex<Wal>,
}

impl DataNode {
    pub fn new(id: u32) -> DataNode {
        DataNode {
            id,
            alive: AtomicBool::new(true),
            parts: RwLock::new(FxHashMap::default()),
            wal: Mutex::new(Wal::new()),
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Simulate a crash: the node stops serving. Its in-memory state is
    /// retained so tests can also exercise "restart" (recover + rejoin).
    pub fn kill(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }

    /// Bring the node back (after recovery re-seeds its replicas).
    pub fn revive(&self) {
        self.alive.store(true, Ordering::SeqCst);
    }

    fn check_alive(&self) -> Result<()> {
        if self.is_alive() {
            Ok(())
        } else {
            Err(Error::Unavailable(format!("data node {} is down", self.id)))
        }
    }

    /// Host a new (empty) replica of `def`'s partition `pidx`.
    pub fn host_partition(&self, def: Arc<TableDef>, pidx: usize) -> Result<()> {
        let mut g = self.parts.write().unwrap();
        let key = (def.name.clone(), pidx);
        if g.contains_key(&key) {
            return Err(Error::Catalog(format!(
                "node {} already hosts {}[{}]",
                self.id, key.0, key.1
            )));
        }
        g.insert(key, Arc::new(RwLock::new(PartitionStore::new(def))));
        Ok(())
    }

    /// Drop a hosted replica (re-replication source cleanup).
    pub fn drop_partition(&self, table: &str, pidx: usize) {
        self.parts.write().unwrap().remove(&(table.to_string(), pidx));
    }

    /// Handle to a hosted replica; errors if the node is down or does not
    /// host the replica.
    pub fn partition(&self, table: &str, pidx: usize) -> Result<Arc<RwLock<PartitionStore>>> {
        self.check_alive()?;
        self.partition_even_if_dead(table, pidx)
    }

    /// Same as [`partition`] but usable on a dead node (recovery path).
    pub fn partition_even_if_dead(
        &self,
        table: &str,
        pidx: usize,
    ) -> Result<Arc<RwLock<PartitionStore>>> {
        self.parts
            .read()
            .unwrap()
            .get(&(table.to_string(), pidx))
            .cloned()
            .ok_or_else(|| {
                Error::Unavailable(format!("node {} does not host {table}[{pidx}]", self.id))
            })
    }

    /// Whether a replica of `table[pidx]` lives here.
    pub fn hosts(&self, table: &str, pidx: usize) -> bool {
        self.parts.read().unwrap().contains_key(&(table.to_string(), pidx))
    }

    /// All replica keys hosted here.
    pub fn hosted_keys(&self) -> Vec<PartKey> {
        self.parts.read().unwrap().keys().cloned().collect()
    }

    /// Append a committed op to the node WAL.
    pub fn log(&self, op: LogOp) -> Result<u64> {
        self.wal.lock().unwrap().append(op)
    }

    /// Apply a redo op to the local replica (replication / recovery).
    ///
    /// Slot-addressed: the WAL records the slot chosen by the primary, and
    /// the replica's slab must land the row in the same slot — asserted so
    /// replica divergence is caught immediately rather than silently.
    pub fn apply(&self, op: &LogOp) -> Result<()> {
        match op {
            LogOp::Insert { table, pidx, slot, row } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let mut p = part.write().unwrap();
                let got = p.insert(row.as_ref().clone())?;
                if got != *slot {
                    return Err(Error::TxnAborted(format!(
                        "replica slot divergence on {table}[{pidx}]: {got} != {slot}"
                    )));
                }
                Ok(())
            }
            LogOp::Update { table, pidx, slot, row } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let r = part.write().unwrap().update(*slot, row.as_ref().clone());
                r
            }
            LogOp::Delete { table, pidx, slot } => {
                let part = self.partition_even_if_dead(table, *pidx)?;
                let r = part.write().unwrap().delete(*slot).map(|_| ());
                r
            }
        }
    }

    /// Total resident bytes across hosted replicas.
    pub fn approx_bytes(&self) -> usize {
        let g = self.parts.read().unwrap();
        g.values().map(|p| p.read().unwrap().approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::{ColumnType, Row, Schema, Value};

    fn def() -> Arc<TableDef> {
        Arc::new(
            TableDef::new(
                "t",
                Schema::of(&[("id", ColumnType::Int), ("v", ColumnType::Float)]),
            )
            .with_primary_key("id")
            .unwrap(),
        )
    }

    #[test]
    fn host_and_access_partitions() {
        let n = DataNode::new(0);
        n.host_partition(def(), 0).unwrap();
        n.host_partition(def(), 1).unwrap();
        assert!(n.hosts("t", 0));
        assert!(!n.hosts("t", 2));
        assert!(n.partition("t", 0).is_ok());
        assert!(n.partition("t", 2).is_err());
        assert!(n.host_partition(def(), 0).is_err(), "double-host rejected");
        assert_eq!(n.hosted_keys().len(), 2);
    }

    #[test]
    fn kill_blocks_access_but_preserves_state() {
        let n = DataNode::new(1);
        n.host_partition(def(), 0).unwrap();
        let p = n.partition("t", 0).unwrap();
        p.write()
            .unwrap()
            .insert(Row::new(vec![Value::Int(1), Value::Float(1.0)]))
            .unwrap();
        n.kill();
        assert!(!n.is_alive());
        assert!(n.partition("t", 0).is_err());
        // recovery path still reaches the data
        let p = n.partition_even_if_dead("t", 0).unwrap();
        assert_eq!(p.read().unwrap().len(), 1);
        n.revive();
        assert!(n.partition("t", 0).is_ok());
    }

    #[test]
    fn apply_replicates_ops_with_slot_check() {
        let primary = DataNode::new(0);
        let backup = DataNode::new(1);
        primary.host_partition(def(), 0).unwrap();
        backup.host_partition(def(), 0).unwrap();

        let row = Row::new(vec![Value::Int(7), Value::Float(3.0)]);
        let part = primary.partition("t", 0).unwrap();
        let slot = part.write().unwrap().insert(row.clone()).unwrap();
        let op = LogOp::Insert { table: "t".into(), pidx: 0, slot, row: Arc::new(row) };
        backup.apply(&op).unwrap();
        let bp = backup.partition("t", 0).unwrap();
        assert_eq!(bp.read().unwrap().len(), 1);

        // divergence detection: applying the same insert again must fail
        assert!(backup.apply(&op).is_err());
    }

    #[test]
    fn wal_appends_through_node() {
        let n = DataNode::new(0);
        n.log(LogOp::Delete { table: "t".into(), pidx: 0, slot: 3 }).unwrap();
        assert_eq!(n.wal.lock().unwrap().len(), 1);
    }
}
