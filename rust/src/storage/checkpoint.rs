//! On-disk checkpoints and recovery.
//!
//! Two granularities share one line encoding (the WAL's):
//!
//! 1. **Whole-cluster checkpoints** ([`checkpoint`] / [`recover`]): the
//!    original export/import path — serialize the catalog and every table's
//!    rows to a directory, rebuild a fresh cluster from it. Still the right
//!    tool for backups and migrations.
//! 2. **Per-partition fuzzy checkpoints** ([`checkpoint_node`]): the
//!    durability path. Each hosted partition replica is dumped on its own —
//!    slot-preserving rows plus the partition's LSN (`version`), epoch and
//!    slab capacity — under nothing more than that partition's read latch
//!    (no 2PL freeze; "fuzzy" across partitions, consistent within one).
//!    Cutting a partition checkpoint truncates its WAL segment, so the
//!    retained redo tail stays bounded. Recovery loads the checkpoint and
//!    replays the tail (`DbCluster::restart_node`).
//!
//! Checkpoints are incremental per partition: a partition whose version
//! already matches its on-disk checkpoint is skipped.

use crate::storage::cluster::{ClusterConfig, DbCluster};
use crate::storage::table_def::{Partitioning, TableDef};
use crate::storage::value::{Column, ColumnType, Row, Schema};
use crate::storage::wal::{decode_value, encode_value, fnv1a32, fnv1a32_fold};
use crate::util::failpoint;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

/// Write a checkpoint of every table to `dir` (one `.tbl` file per table).
///
/// Each file: a header line describing the definition, then one line per
/// row. Rows are read under per-partition read locks, so the checkpoint of
/// each partition is internally consistent.
pub fn checkpoint(cluster: &DbCluster, dir: &Path) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut files = 0;
    for table in cluster.tables() {
        let rs = cluster.query(&format!("SELECT * FROM {table}"))?;
        let def = cluster_def(cluster, &table)?;
        let path = dir.join(format!("{table}.tbl"));
        let f = std::fs::File::create(&path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", def_header(&def))?;
        for row in &rs.rows {
            let line: Vec<String> = row.values.iter().map(encode_value).collect();
            writeln!(w, "{}", line.join("\t"))?;
        }
        w.flush()?;
        files += 1;
    }
    Ok(files)
}

/// Rebuild a cluster from a checkpoint directory.
pub fn recover(dir: &Path, config: ClusterConfig) -> Result<Arc<DbCluster>> {
    let cluster = DbCluster::start(config)?;
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "tbl"))
        .collect();
    entries.sort();
    for path in entries {
        let f = std::fs::File::open(&path)?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Parse(format!("empty checkpoint file {path:?}")))??;
        let def = parse_def_header(&header)?;
        let table = def.name.clone();
        let ncols = def.schema.len();
        cluster.create_table(def)?;
        // Bulk insert via the SQL path would re-parse every value; go
        // through INSERT statements built from decoded values instead.
        let mut batch: Vec<String> = Vec::new();
        for line in lines {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let vals = line.split('\t').map(decode_value).collect::<Result<Vec<_>>>()?;
            if vals.len() != ncols {
                return Err(Error::Parse(format!(
                    "checkpoint row arity {} != {} in {path:?}",
                    vals.len(),
                    ncols
                )));
            }
            let rendered: Vec<String> = vals
                .iter()
                .map(|v| match v {
                    crate::storage::value::Value::Null => "NULL".to_string(),
                    crate::storage::value::Value::Int(i) => i.to_string(),
                    crate::storage::value::Value::Float(f) => {
                        if f.is_finite() {
                            format!("{f:?}")
                        } else {
                            "NULL".to_string()
                        }
                    }
                    crate::storage::value::Value::Bool(b) => b.to_string().to_uppercase(),
                    crate::storage::value::Value::Str(s) => {
                        format!("'{}'", crate::storage::sql::escape_sql_str(s))
                    }
                })
                .collect();
            batch.push(format!("({})", rendered.join(", ")));
            if batch.len() >= 256 {
                cluster.execute(&format!("INSERT INTO {table} VALUES {}", batch.join(", ")))?;
                batch.clear();
            }
        }
        if !batch.is_empty() {
            cluster.execute(&format!("INSERT INTO {table} VALUES {}", batch.join(", ")))?;
        }
    }
    Ok(cluster)
}

// ---------- per-partition fuzzy checkpoints (the durability path) ----------

/// Outcome of one [`checkpoint_node`] pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeCheckpointReport {
    /// Partition checkpoints (re)written this pass.
    pub written: usize,
    /// Partitions skipped because their on-disk checkpoint already covers
    /// the current version (the incremental rule).
    pub skipped: usize,
}

/// A loaded per-partition checkpoint.
pub struct PartitionCheckpoint {
    pub def: TableDef,
    pub pidx: usize,
    /// Partition LSN at the cut.
    pub version: u64,
    /// Epoch fence at the cut.
    pub epoch: u64,
    /// Slab capacity at the cut (holes included).
    pub cap: usize,
    /// Live rows with their slots.
    pub rows: Vec<(usize, Row)>,
}

/// Checkpoint file name of one partition replica inside a node directory.
pub fn partition_ckpt_name(table: &str, pidx: usize) -> String {
    format!("{}.p{pidx}.ckpt", table.to_lowercase())
}

/// WAL segment file name of one partition replica inside a node directory.
pub fn partition_wal_name(table: &str, pidx: usize) -> String {
    format!("{}.p{pidx}.wal", table.to_lowercase())
}

/// Cut incremental, fuzzy checkpoints of every partition replica hosted by
/// `node_id`, into the node's durability directory. Each partition is
/// dumped under its own read latch (workers keep claiming throughout — no
/// global freeze), written to a temp file and renamed into place, and its
/// WAL segment is truncated up to the checkpointed LSN.
pub fn checkpoint_node(cluster: &DbCluster, node_id: u32) -> Result<NodeCheckpointReport> {
    let d = cluster
        .durability()
        .ok_or_else(|| Error::Engine("checkpoint_node requires a durability dir".into()))?;
    let dir = d.dir.join(format!("node{node_id}"));
    std::fs::create_dir_all(&dir)?;
    let node = cluster
        .node(node_id)
        .ok_or_else(|| Error::Unavailable(format!("no node {node_id}")))?
        .clone();
    let mut report = NodeCheckpointReport::default();
    let mut keys = node.hosted_keys();
    keys.sort();
    for (table, pidx) in keys {
        let store = node.partition_even_if_dead(&table, pidx)?;
        let fname = dir.join(partition_ckpt_name(&table, pidx));
        let dumped = {
            let g = store.read().unwrap();
            // Incremental skip needs version *and* epoch to match: a
            // rejoin hand-off (or heal) can re-stamp a partition's epoch
            // fence without any write, and a checkpoint that kept the old
            // epoch would weaken fencing on the next restart.
            if read_ckpt_meta(&fname) == Some((g.version, g.epoch)) {
                None // incremental: nothing changed since the last cut
            } else {
                let (cap, rows) = g.snapshot_slotted();
                Some((g.def().clone(), g.version, g.epoch, cap, rows))
            }
            // read latch drops here: the dump below runs without it
        };
        let Some((def, version, epoch, cap, rows)) = dumped else {
            report.skipped += 1;
            continue;
        };
        let tmp = dir.join(format!("{}.tmp", partition_ckpt_name(&table, pidx)));
        failpoint::hit("ckpt-before-tmp-write")?;
        {
            let f = std::fs::File::create(&tmp)?;
            let mut w = BufWriter::new(f);
            // Stream a FNV-1a32 over every body byte and append it as a
            // `#<hex>` trailer line: load rejects a checkpoint whose body
            // was torn or bit-flipped instead of deserializing garbage.
            let mut sum = fnv1a32(&[]);
            let mut put = |w: &mut BufWriter<std::fs::File>, line: String| -> Result<()> {
                writeln!(w, "{line}")?;
                sum = fnv1a32_fold(sum, line.as_bytes());
                sum = fnv1a32_fold(sum, b"\n");
                Ok(())
            };
            put(&mut w, def_header(&def))?;
            put(&mut w, format!("{pidx}\x1f{version}\x1f{epoch}\x1f{cap}"))?;
            for (slot, row) in &rows {
                let vals: Vec<String> = row.values.iter().map(encode_value).collect();
                put(&mut w, format!("{slot}\t{}", vals.join("\t")))?;
            }
            writeln!(w, "#{sum:08x}")?;
            w.flush()?;
        }
        failpoint::hit("ckpt-after-tmp-write")?;
        std::fs::rename(&tmp, &fname)?;
        failpoint::hit("ckpt-after-rename")?;
        // the cut: redo at or below `version` is covered by the checkpoint
        node.wal.lock().unwrap().truncate_upto(&table, pidx, version)?;
        report.written += 1;
    }
    Ok(report)
}

/// Load one per-partition checkpoint file, verifying its checksum trailer.
///
/// A checkpoint whose `#<fnv1a32>` trailer is missing (torn write) or does
/// not match the body (bit rot, manual corruption) fails with
/// `Error::Parse` **before** any row is deserialized — callers fall back to
/// WAL replay or cross-node shipping rather than loading garbage.
pub fn load_partition_checkpoint(path: &Path) -> Result<PartitionCheckpoint> {
    let text = std::fs::read_to_string(path)?;
    let trimmed = text.trim_end_matches('\n');
    let (body, trailer) = match trimmed.rfind('\n') {
        Some(i) => (&text[..i + 1], &trimmed[i + 1..]),
        None => {
            return Err(Error::Parse(format!("truncated partition checkpoint {path:?}")));
        }
    };
    let want = trailer
        .strip_prefix('#')
        .and_then(|h| u32::from_str_radix(h, 16).ok())
        .ok_or_else(|| {
            Error::Parse(format!("partition checkpoint {path:?} missing checksum trailer"))
        })?;
    let got = fnv1a32(body.as_bytes());
    if got != want {
        return Err(Error::Parse(format!(
            "partition checkpoint {path:?} checksum mismatch (trailer {want:08x}, body {got:08x})"
        )));
    }
    let mut lines = body.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse(format!("empty partition checkpoint {path:?}")))?;
    let def = parse_def_header(header)?;
    let meta = lines
        .next()
        .ok_or_else(|| Error::Parse(format!("partition checkpoint missing meta {path:?}")))?;
    let parts: Vec<&str> = meta.split('\x1f').collect();
    if parts.len() != 4 {
        return Err(Error::Parse(format!("bad partition checkpoint meta: {meta}")));
    }
    let pidx: usize = parts[0]
        .parse()
        .map_err(|e| Error::Parse(format!("bad ckpt pidx: {e}")))?;
    let version: u64 = parts[1]
        .parse()
        .map_err(|e| Error::Parse(format!("bad ckpt version: {e}")))?;
    let epoch: u64 = parts[2]
        .parse()
        .map_err(|e| Error::Parse(format!("bad ckpt epoch: {e}")))?;
    let cap: usize = parts[3]
        .parse()
        .map_err(|e| Error::Parse(format!("bad ckpt cap: {e}")))?;
    let ncols = def.schema.len();
    let mut rows = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let slot: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("checkpoint row missing slot".into()))?;
        let vals = it.map(decode_value).collect::<Result<Vec<_>>>()?;
        if vals.len() != ncols {
            return Err(Error::Parse(format!(
                "checkpoint row arity {} != {ncols} in {path:?}",
                vals.len()
            )));
        }
        rows.push((slot, Row::new(vals)));
    }
    Ok(PartitionCheckpoint { def, pidx, version, epoch, cap, rows })
}

/// `(version, epoch)` recorded in an existing partition checkpoint (the
/// incremental skip check); `None` when the file is missing or unreadable.
fn read_ckpt_meta(path: &Path) -> Option<(u64, u64)> {
    let f = std::fs::File::open(path).ok()?;
    let mut lines = BufReader::new(f).lines();
    let _header = lines.next()?.ok()?;
    let meta = lines.next()?.ok()?;
    let mut it = meta.split('\x1f').skip(1);
    let version: u64 = it.next()?.parse().ok()?;
    let epoch: u64 = it.next()?.parse().ok()?;
    Some((version, epoch))
}

fn cluster_def(cluster: &DbCluster, table: &str) -> Result<TableDefView> {
    // The cluster doesn't expose TableDef directly; reconstruct what the
    // header needs from a probing SELECT plus the catalog surface we do
    // have. To keep this honest we add an accessor instead:
    cluster.table_def(table)
}

/// Borrowed alias so the header helpers read naturally.
type TableDefView = Arc<TableDef>;

fn def_header(def: &TableDef) -> String {
    let mut s = String::new();
    s.push_str(&def.name);
    s.push('\x1f');
    let cols: Vec<String> = def
        .schema
        .columns
        .iter()
        .map(|c| format!("{}:{}:{}", c.name, c.ty.name(), u8::from(c.nullable)))
        .collect();
    s.push_str(&cols.join(","));
    s.push('\x1f');
    match &def.partitioning {
        Partitioning::Single => s.push('-'),
        Partitioning::Hash { column, partitions } => {
            s.push_str(&format!("{column}:{partitions}"));
            if !def.split_classes.is_empty() {
                // Optional third bit: post-split congruence classes
                // "m.r;m.r;…" — absent for never-split tables so old
                // checkpoints stay parseable.
                let classes: Vec<String> =
                    def.split_classes.iter().map(|(m, r)| format!("{m}.{r}")).collect();
                s.push(':');
                s.push_str(&classes.join(";"));
            }
        }
    }
    s.push('\x1f');
    s.push_str(def.primary_key.as_deref().unwrap_or("-"));
    s.push('\x1f');
    s.push_str(&def.indexes.join(","));
    s
}

fn parse_def_header(h: &str) -> Result<TableDef> {
    let parts: Vec<&str> = h.split('\x1f').collect();
    if parts.len() != 5 {
        return Err(Error::Parse(format!("bad checkpoint header: {h}")));
    }
    let name = parts[0].to_string();
    let columns = parts[1]
        .split(',')
        .map(|c| {
            let bits: Vec<&str> = c.split(':').collect();
            if bits.len() != 3 {
                return Err(Error::Parse(format!("bad column spec '{c}'")));
            }
            Ok(Column {
                name: bits[0].to_string(),
                ty: ColumnType::parse(bits[1])?,
                nullable: bits[2] == "1",
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut def = TableDef::new(name, Schema::new(columns)?);
    if parts[2] != "-" {
        let bits: Vec<&str> = parts[2].split(':').collect();
        let n: usize = bits
            .get(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse(format!("bad partition spec '{}'", parts[2])))?;
        def = def.partition_by_hash(bits[0], n)?;
        if let Some(spec) = bits.get(2) {
            let classes = spec
                .split(';')
                .map(|c| {
                    let (m, r) = c
                        .split_once('.')
                        .ok_or_else(|| Error::Parse(format!("bad split class '{c}'")))?;
                    Ok((
                        m.parse()
                            .map_err(|_| Error::Parse(format!("bad split class '{c}'")))?,
                        r.parse()
                            .map_err(|_| Error::Parse(format!("bad split class '{c}'")))?,
                    ))
                })
                .collect::<Result<Vec<(i64, i64)>>>()?;
            def = def.with_split_classes(classes)?;
        }
    }
    if parts[3] != "-" {
        def = def.with_primary_key(parts[3])?;
    }
    if !parts[4].is_empty() {
        for ix in parts[4].split(',') {
            def = def.with_index(ix)?;
        }
    }
    Ok(def)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::DurabilityConfig;
    use crate::storage::value::Value;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("schaladb-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn checkpoint_recover_roundtrip() {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE wq (taskid INT NOT NULL, wid INT NOT NULL, status TEXT, dur FLOAT) \
             PARTITION BY HASH(wid) PARTITIONS 4 PRIMARY KEY (taskid) INDEX (status)",
        )
        .unwrap();
        c.exec("CREATE TABLE meta (k TEXT, v TEXT)").unwrap();
        for i in 0..40 {
            c.execute(&format!(
                "INSERT INTO wq (taskid, wid, status, dur) VALUES ({i}, {}, 'READY', {}.25)",
                i % 4,
                i
            ))
            .unwrap();
        }
        c.execute("INSERT INTO meta (k, v) VALUES ('wf', 'risers'), ('note', 'it''s ok')")
            .unwrap();

        let dir = tmpdir("roundtrip");
        let files = checkpoint(&c, &dir).unwrap();
        assert_eq!(files, 2);

        let r = recover(&dir, ClusterConfig::default()).unwrap();
        assert_eq!(r.table_rows("wq").unwrap(), 40);
        assert_eq!(r.table_rows("meta").unwrap(), 2);
        // partitioning preserved: worker-pinned query routes correctly
        let rs = r.query("SELECT COUNT(*) FROM wq WHERE wid = 2").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(10));
        // quoted string survived
        let rs = r.query("SELECT v FROM meta WHERE k = 'note'").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("it's ok"));
        // PK constraint re-armed after recovery
        assert!(r
            .execute("INSERT INTO wq (taskid, wid, status, dur) VALUES (0, 0, 'X', 1.0)")
            .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_empty_table() {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec("CREATE TABLE empty (a INT, b TEXT)").unwrap();
        let dir = tmpdir("empty");
        checkpoint(&c, &dir).unwrap();
        let r = recover(&dir, ClusterConfig::default()).unwrap();
        assert_eq!(r.table_rows("empty").unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partition_checkpoints_are_incremental_and_slot_exact() {
        let dir = tmpdir("partial");
        let c = DbCluster::start(
            ClusterConfig::builder()
                .durability(DurabilityConfig::new(dir.clone(), 4))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE wq (taskid INT NOT NULL, wid INT NOT NULL, status TEXT) \
             PARTITION BY HASH(wid) PARTITIONS 2 PRIMARY KEY (taskid)",
        )
        .unwrap();
        for i in 0..20 {
            c.execute(&format!(
                "INSERT INTO wq (taskid, wid, status) VALUES ({i}, {}, 'READY')",
                i % 2
            ))
            .unwrap();
        }
        // a hole so the slot-preserving format has something to preserve
        c.execute("DELETE FROM wq WHERE taskid = 4").unwrap();

        let r = checkpoint_node(&c, 0).unwrap();
        assert!(r.written > 0);
        assert_eq!(r.skipped, 0);
        // second pass with no writes in between: everything skips
        let r2 = checkpoint_node(&c, 0).unwrap();
        assert_eq!(r2.written, 0);
        assert_eq!(r2.skipped, r.written);
        // a write dirties exactly one partition
        c.execute("UPDATE wq SET status = 'RUNNING' WHERE taskid = 7").unwrap();
        let r3 = checkpoint_node(&c, 0).unwrap();
        assert_eq!(r3.written + r3.skipped, r.written);
        assert!(r3.written >= 1);

        // the file round-trips with slots, version, epoch and capacity
        let node_dir = dir.join("node0");
        let mut found = false;
        for e in std::fs::read_dir(&node_dir).unwrap() {
            let p = e.unwrap().path();
            if p.extension().map_or(false, |x| x == "ckpt") {
                let ck = load_partition_checkpoint(&p).unwrap();
                assert_eq!(ck.def.name, "wq");
                assert!(ck.cap >= ck.rows.len());
                assert!(ck.version > 0);
                found = true;
            }
        }
        assert!(found, "node0 must have at least one partition checkpoint");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A rejoin hand-off re-stamps partition epochs without changing
    /// versions; the post-rejoin checkpoint must rewrite the files (not
    /// skip on the matching version), or a later restart loads a stale
    /// epoch fence.
    #[test]
    fn epoch_only_change_rewrites_checkpoint() {
        use crate::storage::replication::AvailabilityManager;
        let dir = tmpdir("epoch-skip");
        let c = DbCluster::start(
            ClusterConfig::builder()
                .durability(DurabilityConfig::new(dir.clone(), 1))
                .build()
                .unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE wq (taskid INT NOT NULL, wid INT NOT NULL, status TEXT) \
             PARTITION BY HASH(wid) PARTITIONS 2 PRIMARY KEY (taskid)",
        )
        .unwrap();
        for i in 0..10 {
            c.execute(&format!(
                "INSERT INTO wq (taskid, wid, status) VALUES ({i}, {}, 'READY')",
                i % 2
            ))
            .unwrap();
        }
        // baseline checkpoints at epoch 0
        assert!(checkpoint_node(&c, 0).unwrap().written > 0);
        // promotion bumps the epoch; node 0 rejoins with unchanged
        // versions, and the final cut's checkpoint must re-stamp the disk
        let am = AvailabilityManager::new(c.clone());
        c.kill_node(0).unwrap();
        am.sweep().unwrap();
        let epoch = c.cluster_epoch();
        assert!(epoch > 0);
        c.restart_node(0).unwrap();
        let r = am.sweep().unwrap();
        assert_eq!(r.rejoined, 1);
        let node_dir = dir.join("node0");
        let mut checked = 0;
        for e in std::fs::read_dir(&node_dir).unwrap() {
            let p = e.unwrap().path();
            if p.extension().map_or(false, |x| x == "ckpt") {
                let ck = load_partition_checkpoint(&p).unwrap();
                assert_eq!(
                    ck.epoch, epoch,
                    "checkpoint must be rewritten when only the epoch moved"
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn header_roundtrip() {
        let def = TableDef::new(
            "t",
            Schema::of(&[("a", ColumnType::Int), ("b", ColumnType::Str)]),
        )
        .partition_by_hash("a", 8)
        .unwrap()
        .with_primary_key("a")
        .unwrap()
        .with_index("b")
        .unwrap();
        let h = def_header(&def);
        let back = parse_def_header(&h).unwrap();
        assert_eq!(back.name, "t");
        assert_eq!(back.num_partitions(), 8);
        assert_eq!(back.primary_key.as_deref(), Some("a"));
        assert_eq!(back.indexes, vec!["b".to_string()]);
    }

    #[test]
    fn bad_headers_rejected() {
        assert!(parse_def_header("no-separators").is_err());
        assert!(parse_def_header("t\x1fbad-col\x1f-\x1f-\x1f").is_err());
        assert!(load_partition_checkpoint(Path::new("/nonexistent/x.ckpt")).is_err());
    }
}
