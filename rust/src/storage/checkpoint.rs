//! On-disk checkpoints and recovery.
//!
//! The paper runs the DBMS "in-memory ... with occasional on-disk
//! checkpoints". A checkpoint serializes the catalog (table definitions +
//! partitioning) and every partition's rows to a directory; recovery
//! rebuilds a fresh cluster from it. Format is the same line encoding the
//! WAL uses, so the two durability paths share code.

use crate::storage::cluster::{ClusterConfig, DbCluster};
use crate::storage::table_def::{Partitioning, TableDef};
use crate::storage::value::{Column, ColumnType, Row, Schema};
use crate::storage::wal::{decode_value, encode_value};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

/// Write a checkpoint of every table to `dir` (one `.tbl` file per table).
///
/// Each file: a header line describing the definition, then one line per
/// row. Rows are read under per-partition read locks, so the checkpoint of
/// each partition is internally consistent.
pub fn checkpoint(cluster: &DbCluster, dir: &Path) -> Result<usize> {
    std::fs::create_dir_all(dir)?;
    let mut files = 0;
    for table in cluster.tables() {
        let rs = cluster.query(&format!("SELECT * FROM {table}"))?;
        let def = cluster_def(cluster, &table)?;
        let path = dir.join(format!("{table}.tbl"));
        let f = std::fs::File::create(&path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "{}", def_header(&def))?;
        for row in &rs.rows {
            let line: Vec<String> = row.values.iter().map(encode_value).collect();
            writeln!(w, "{}", line.join("\t"))?;
        }
        w.flush()?;
        files += 1;
    }
    Ok(files)
}

/// Rebuild a cluster from a checkpoint directory.
pub fn recover(dir: &Path, config: ClusterConfig) -> Result<Arc<DbCluster>> {
    let cluster = DbCluster::start(config)?;
    let mut entries: Vec<_> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "tbl"))
        .collect();
    entries.sort();
    for path in entries {
        let f = std::fs::File::open(&path)?;
        let mut lines = BufReader::new(f).lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Parse(format!("empty checkpoint file {path:?}")))??;
        let def = parse_def_header(&header)?;
        let table = def.name.clone();
        let ncols = def.schema.len();
        cluster.create_table(def)?;
        // Bulk insert via the SQL path would re-parse every value; go
        // through INSERT statements built from decoded values instead.
        let mut batch: Vec<String> = Vec::new();
        for line in lines {
            let line = line?;
            if line.is_empty() {
                continue;
            }
            let vals = line.split('\t').map(decode_value).collect::<Result<Vec<_>>>()?;
            if vals.len() != ncols {
                return Err(Error::Parse(format!(
                    "checkpoint row arity {} != {} in {path:?}",
                    vals.len(),
                    ncols
                )));
            }
            let rendered: Vec<String> = vals
                .iter()
                .map(|v| match v {
                    crate::storage::value::Value::Null => "NULL".to_string(),
                    crate::storage::value::Value::Int(i) => i.to_string(),
                    crate::storage::value::Value::Float(f) => {
                        if f.is_finite() {
                            format!("{f:?}")
                        } else {
                            "NULL".to_string()
                        }
                    }
                    crate::storage::value::Value::Bool(b) => b.to_string().to_uppercase(),
                    crate::storage::value::Value::Str(s) => {
                        format!("'{}'", crate::storage::sql::escape_sql_str(s))
                    }
                })
                .collect();
            batch.push(format!("({})", rendered.join(", ")));
            if batch.len() >= 256 {
                cluster.execute(&format!("INSERT INTO {table} VALUES {}", batch.join(", ")))?;
                batch.clear();
            }
        }
        if !batch.is_empty() {
            cluster.execute(&format!("INSERT INTO {table} VALUES {}", batch.join(", ")))?;
        }
    }
    Ok(cluster)
}

fn cluster_def(cluster: &DbCluster, table: &str) -> Result<TableDefView> {
    // The cluster doesn't expose TableDef directly; reconstruct what the
    // header needs from a probing SELECT plus the catalog surface we do
    // have. To keep this honest we add an accessor instead:
    cluster.table_def(table)
}

/// Borrowed alias so the header helpers read naturally.
type TableDefView = Arc<TableDef>;

fn def_header(def: &TableDef) -> String {
    let mut s = String::new();
    s.push_str(&def.name);
    s.push('\x1f');
    let cols: Vec<String> = def
        .schema
        .columns
        .iter()
        .map(|c| format!("{}:{}:{}", c.name, c.ty.name(), u8::from(c.nullable)))
        .collect();
    s.push_str(&cols.join(","));
    s.push('\x1f');
    match &def.partitioning {
        Partitioning::Single => s.push('-'),
        Partitioning::Hash { column, partitions } => {
            s.push_str(&format!("{column}:{partitions}"))
        }
    }
    s.push('\x1f');
    s.push_str(def.primary_key.as_deref().unwrap_or("-"));
    s.push('\x1f');
    s.push_str(&def.indexes.join(","));
    s
}

fn parse_def_header(h: &str) -> Result<TableDef> {
    let parts: Vec<&str> = h.split('\x1f').collect();
    if parts.len() != 5 {
        return Err(Error::Parse(format!("bad checkpoint header: {h}")));
    }
    let name = parts[0].to_string();
    let columns = parts[1]
        .split(',')
        .map(|c| {
            let bits: Vec<&str> = c.split(':').collect();
            if bits.len() != 3 {
                return Err(Error::Parse(format!("bad column spec '{c}'")));
            }
            Ok(Column {
                name: bits[0].to_string(),
                ty: ColumnType::parse(bits[1])?,
                nullable: bits[2] == "1",
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut def = TableDef::new(name, Schema::new(columns)?);
    if parts[2] != "-" {
        let bits: Vec<&str> = parts[2].split(':').collect();
        let n: usize = bits
            .get(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse(format!("bad partition spec '{}'", parts[2])))?;
        def = def.partition_by_hash(bits[0], n)?;
    }
    if parts[3] != "-" {
        def = def.with_primary_key(parts[3])?;
    }
    if !parts[4].is_empty() {
        for ix in parts[4].split(',') {
            def = def.with_index(ix)?;
        }
    }
    Ok(def)
}

// Row is referenced by the doc comment narrative; silence unused import on
// some cfgs.
#[allow(unused)]
fn _t(_r: Row) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::Value;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("schaladb-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn checkpoint_recover_roundtrip() {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE wq (taskid INT NOT NULL, wid INT NOT NULL, status TEXT, dur FLOAT) \
             PARTITION BY HASH(wid) PARTITIONS 4 PRIMARY KEY (taskid) INDEX (status)",
        )
        .unwrap();
        c.exec("CREATE TABLE meta (k TEXT, v TEXT)").unwrap();
        for i in 0..40 {
            c.execute(&format!(
                "INSERT INTO wq (taskid, wid, status, dur) VALUES ({i}, {}, 'READY', {}.25)",
                i % 4,
                i
            ))
            .unwrap();
        }
        c.execute("INSERT INTO meta (k, v) VALUES ('wf', 'risers'), ('note', 'it''s ok')")
            .unwrap();

        let dir = tmpdir("roundtrip");
        let files = checkpoint(&c, &dir).unwrap();
        assert_eq!(files, 2);

        let r = recover(&dir, ClusterConfig::default()).unwrap();
        assert_eq!(r.table_rows("wq").unwrap(), 40);
        assert_eq!(r.table_rows("meta").unwrap(), 2);
        // partitioning preserved: worker-pinned query routes correctly
        let rs = r.query("SELECT COUNT(*) FROM wq WHERE wid = 2").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(10));
        // quoted string survived
        let rs = r.query("SELECT v FROM meta WHERE k = 'note'").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("it's ok"));
        // PK constraint re-armed after recovery
        assert!(r
            .execute("INSERT INTO wq (taskid, wid, status, dur) VALUES (0, 0, 'X', 1.0)")
            .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_empty_table() {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec("CREATE TABLE empty (a INT, b TEXT)").unwrap();
        let dir = tmpdir("empty");
        checkpoint(&c, &dir).unwrap();
        let r = recover(&dir, ClusterConfig::default()).unwrap();
        assert_eq!(r.table_rows("empty").unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn header_roundtrip() {
        let def = TableDef::new(
            "t",
            Schema::of(&[("a", ColumnType::Int), ("b", ColumnType::Str)]),
        )
        .partition_by_hash("a", 8)
        .unwrap()
        .with_primary_key("a")
        .unwrap()
        .with_index("b")
        .unwrap();
        let h = def_header(&def);
        let back = parse_def_header(&h).unwrap();
        assert_eq!(back.name, "t");
        assert_eq!(back.num_partitions(), 8);
        assert_eq!(back.primary_key.as_deref(), Some("a"));
        assert_eq!(back.indexes, vec!["b".to_string()]);
    }

    #[test]
    fn bad_headers_rejected() {
        assert!(parse_def_header("no-separators").is_err());
        assert!(parse_def_header("t\x1fbad-col\x1f-\x1f-\x1f").is_err());
    }
}
