//! Workload definitions: the Risers Fatigue Analysis workflow (paper §5.1,
//! Figure 8) and the synthetic workloads derived from it for Experiments
//! 1–8.

use crate::coordinator::payload::{Payload, SyntheticKind};
use crate::coordinator::workflow::{ActivitySpec, Operator, WorkflowSpec};
use crate::util::rng::Rng;

/// The seven linked activities of the Risers Fatigue Analysis workflow.
/// Environmental conditions (wind, wave, depth) flow through preprocessing,
/// stress analysis, curvature selection, wear-and-tear calculation, riser
/// analysis (the activity users steer, Q8), result compression, and final
/// gathering.
pub fn risers_activity_names() -> [&'static str; 7] {
    [
        "data_gathering",
        "preprocessing",
        "stress_analysis",
        "stress_critical_case",
        "calculate_wear_and_tear",
        "analyze_risers",
        "compress_results",
    ]
}

/// Risers workflow with pure-Rust synthetic physics (no PJRT needed): use
/// for unit/integration tests and the steering example.
pub fn risers_workflow(conditions: usize) -> WorkflowSpec {
    risers_workflow_with(conditions, None)
}

/// Risers workflow whose stress/wear hot spot runs through a registered
/// artifact runner (the AOT-compiled JAX/Pallas kernel) when `runner` is
/// given.
pub fn risers_workflow_with(conditions: usize, runner: Option<&str>) -> WorkflowSpec {
    let stress_payload = match runner {
        Some(r) => Payload::Artifact { runner: r.to_string() },
        None => Payload::Synthetic { kind: SyntheticKind::RiserStress },
    };
    let wear_payload = match runner {
        Some(r) => Payload::Artifact { runner: format!("{r}_wear") },
        None => Payload::Synthetic { kind: SyntheticKind::WearTear },
    };
    WorkflowSpec::new("risers_fatigue_analysis", conditions)
        .activity(
            ActivitySpec::new(
                "data_gathering",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::PassThrough },
            )
            .with_fields(&["wind", "wave", "depth"]),
        )
        .activity(
            // Pre-Processing produces the curvature components (paper Q7:
            // "cx, cy, cz ... output parameters produced in Pre-Processing")
            ActivitySpec::new("preprocessing", Operator::Map, stress_payload)
                .with_fields(&["cx", "cy", "cz"]),
        )
        .activity(
            // stress analysis consumes and forwards the curvature values
            // (its own heavy lifting happened inside the stress kernel)
            ActivitySpec::new(
                "stress_analysis",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::PassThrough },
            )
            .with_fields(&["cx", "cy", "cz"]),
        )
        .activity(
            // keeps only critical cases (cx above threshold) and forwards
            // the curvature of the survivors to the wear calculation
            ActivitySpec::new(
                "stress_critical_case",
                Operator::Filter { field: "cx", min: 0.0 },
                Payload::Synthetic { kind: SyntheticKind::PassThrough },
            )
            .with_fields(&["cx", "cy", "cz"]),
        )
        .activity(
            ActivitySpec::new("calculate_wear_and_tear", Operator::Map, wear_payload)
                .with_fields(&["f1"]),
        )
        .activity(
            ActivitySpec::new(
                "analyze_risers",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::Quadratic },
            )
            .with_fields(&["x", "y"]),
        )
        .activity(ActivitySpec::new(
            "compress_results",
            Operator::Reduce { fanin: 8 },
            Payload::Sleep { mean_secs: 0.2 },
        ))
}

/// Environmental-condition input tuples for the risers workflow.
pub fn risers_inputs(conditions: usize, seed: u64) -> Vec<Vec<(String, f64)>> {
    let mut rng = Rng::new(seed);
    (0..conditions)
        .map(|_| {
            vec![
                ("wind".to_string(), rng.uniform(0.0, 30.0)),
                ("wave".to_string(), rng.uniform(0.05, 0.4)),
                ("depth".to_string(), rng.uniform(500.0, 2500.0)),
            ]
        })
        .collect()
}

/// A synthetic workload in the paper's two dimensions: total task count and
/// mean task duration (§5.2: "we consider a workload as composed of two
/// factors: task duration and number of tasks").
#[derive(Clone, Copy, Debug)]
pub struct SyntheticWorkload {
    pub total_tasks: usize,
    pub mean_task_secs: f64,
    pub activities: usize,
    pub seed: u64,
}

impl SyntheticWorkload {
    pub fn new(total_tasks: usize, mean_task_secs: f64) -> SyntheticWorkload {
        SyntheticWorkload { total_tasks, mean_task_secs, activities: 3, seed: 1234 }
    }

    /// Build the workflow spec: a chain of Map activities sized so the total
    /// task count matches (the risers workflow's structure, durations
    /// synthesized — exactly how the paper generated its workloads).
    pub fn workflow(&self) -> WorkflowSpec {
        let acts = self.activities.max(1);
        let per_activity = (self.total_tasks / acts).max(1);
        let mut wf = WorkflowSpec::new("synthetic_risers", per_activity);
        for i in 0..acts {
            wf = wf.activity(ActivitySpec::new(
                &format!("activity_{}", i + 1),
                Operator::Map,
                Payload::Sleep { mean_secs: self.mean_task_secs },
            ));
        }
        wf
    }

    /// Empty input tuples (duration-only workload).
    pub fn inputs(&self) -> Vec<Vec<(String, f64)>> {
        vec![vec![]; (self.total_tasks / self.activities.max(1)).max(1)]
    }

    /// Actual planned task count (after integer division).
    pub fn planned_tasks(&self) -> usize {
        self.workflow().planned_total_tasks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn risers_has_seven_activities() {
        let wf = risers_workflow(100);
        assert_eq!(wf.activities.len(), 7);
        assert_eq!(wf.activities[1].out_fields, vec!["cx", "cy", "cz"]);
        wf.validate().unwrap();
        // planned: 100 per map activity, filter keeps 100 planned, reduce /8
        let counts = wf.planned_task_counts();
        assert_eq!(counts[0], 100);
        assert_eq!(counts[6], 13);
    }

    #[test]
    fn risers_inputs_are_deterministic_and_bounded() {
        let a = risers_inputs(10, 5);
        let b = risers_inputs(10, 5);
        assert_eq!(a, b);
        for tuple in &a {
            let wind = tuple[0].1;
            let wave = tuple[1].1;
            let depth = tuple[2].1;
            assert!((0.0..30.0).contains(&wind));
            assert!((0.05..0.4).contains(&wave));
            assert!((500.0..2500.0).contains(&depth));
        }
    }

    #[test]
    fn synthetic_workload_matches_paper_dimensions() {
        let w = SyntheticWorkload::new(23_400, 5.0);
        let wf = w.workflow();
        assert_eq!(wf.planned_total_tasks(), 23_400);
        let w = SyntheticWorkload::new(13_000, 60.0);
        // 13000/3 = 4333 per activity, 3 activities = 12999
        assert!((w.planned_tasks() as i64 - 13_000).abs() < 3);
    }
}
