//! Simulation parameters and their calibration anchors.

/// One DBMS access in a task's lifecycle.
#[derive(Clone, Copy, Debug)]
pub struct OpSpec {
    /// Label matching the paper's Figure-12 categories.
    pub kind: &'static str,
    /// Server-side service time (seconds).
    pub service_secs: f64,
    /// Whether the op is an update transaction (claims the partition
    /// exclusively and applies to the backup replica).
    pub write: bool,
    /// Issued during the claim phase (before compute) vs the finish phase.
    pub claim_phase: bool,
}

/// The per-task access profile, calibrated to the paper's Figure 12
/// breakdown: getREADYtasks ≈ 41%, getFileFields ≈ 3.8%, update ops ≈ 53%,
/// total bundle ≈ 0.5 s at low contention (the Experiment-5 anchor).
pub fn default_profile() -> Vec<OpSpec> {
    vec![
        OpSpec { kind: "getREADYtasks", service_secs: 0.200, write: false, claim_phase: true },
        OpSpec { kind: "updateToRUNNING", service_secs: 0.066, write: true, claim_phase: true },
        OpSpec { kind: "getFileFields", service_secs: 0.019, write: false, claim_phase: true },
        OpSpec { kind: "insertDomainData", service_secs: 0.066, write: true, claim_phase: false },
        OpSpec { kind: "insertProvenance", service_secs: 0.066, write: true, claim_phase: false },
        OpSpec { kind: "updateToFINISHED", service_secs: 0.066, write: true, claim_phase: false },
    ]
}

/// Tunable constants of the testbed model. Defaults reproduce the paper's
/// anchor points (see module docs); every experiment bench uses these unless
/// it sweeps the parameter explicitly.
#[derive(Clone, Debug)]
pub struct SimParams {
    /// Worker nodes (W). Paper: up to 40 (usually 39 + supervisor nodes).
    pub workers: usize,
    /// Threads per worker node (12 / 24 / 48 in Experiment 1).
    pub threads: usize,
    /// Physical cores per worker node (StRemi: 24).
    pub cores_per_worker: usize,
    /// SchalaDB data nodes (paper: 2).
    pub data_nodes: usize,
    /// Cores per data node.
    pub cores_per_data_node: usize,

    /// Per-task DBMS access profile.
    pub profile: Vec<OpSpec>,
    /// Client↔DBMS network round trip (Gigabit Ethernet + driver).
    pub net_rtt_secs: f64,

    /// Supervisor poll period.
    pub sup_poll_secs: f64,
    /// Supervisor readiness sweep: cost per WQ task; the sweep takes a
    /// short exclusive window on the WQ, so this term grows with workload
    /// size (the paper's weak-scaling inflation).
    pub sup_scan_secs_per_task: f64,

    /// Oversubscription tax: extra compute fraction per unit of
    /// (threads/cores - 1); Experiment 1 shows mild degradation at 2x.
    pub oversub_tax: f64,

    /// Relative task-duration dispersion (σ/mean) used when synthesizing
    /// durations ("mean task duration" workloads).
    pub duration_cv: f64,

    /// Centralized Chiron: master handling time per message hop.
    pub master_service_secs: f64,
    /// Centralized Chiron: central-DBMS single-partition service multiplier
    /// applied to each op's service time (PostgreSQL under one giant table
    /// + full serialization).
    pub central_db_factor: f64,
    /// MPI message latency per hop.
    pub msg_latency_secs: f64,

    /// When set, a steering client issues the 7-query monitoring mix every
    /// interval (Experiment 7); each query occupies one data-node core.
    pub steering_every_secs: Option<f64>,
    /// Elapsed cost of one steering query ("hundreds of milliseconds").
    pub steering_query_secs: f64,

    pub seed: u64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            workers: 39,
            threads: 24,
            cores_per_worker: 24,
            data_nodes: 2,
            cores_per_data_node: 24,
            profile: default_profile(),
            net_rtt_secs: 0.0003,
            sup_poll_secs: 1.0,
            sup_scan_secs_per_task: 0.000_002,
            oversub_tax: 0.10,
            duration_cv: 0.15,
            master_service_secs: 0.010,
            central_db_factor: 0.30,
            msg_latency_secs: 0.000_3,
            steering_every_secs: None,
            steering_query_secs: 0.3,
            seed: 20210527, // the paper's publication date
        }
    }
}

impl SimParams {
    /// Total worker cores in the deployment.
    pub fn total_cores(&self) -> usize {
        self.workers * self.cores_per_worker
    }

    /// Per-task DBMS bundle service time at zero contention.
    pub fn bundle_secs(&self) -> f64 {
        self.profile.iter().map(|o| o.service_secs).sum()
    }

    /// Set (workers, threads) to match a paper configuration expressed in
    /// total cores (e.g. 960 cores → 40 workers of 24).
    pub fn with_cores(mut self, total_cores: usize, threads: usize) -> SimParams {
        self.workers = (total_cores / self.cores_per_worker).max(1);
        self.threads = threads;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let p = SimParams::default();
        assert_eq!(p.cores_per_worker, 24);
        assert_eq!(p.data_nodes, 2);
        assert_eq!(p.clone().with_cores(960, 24).workers, 40);
        assert_eq!(p.clone().with_cores(960, 24).total_cores(), 960);
        assert_eq!(p.clone().with_cores(120, 12).workers, 5);
    }

    #[test]
    fn profile_matches_figure12_anchors() {
        let p = SimParams::default();
        let bundle = p.bundle_secs();
        assert!((bundle - 0.483).abs() < 1e-9, "Exp-5 anchor drifted: {bundle}");
        // getREADYtasks > 40% of the bundle
        let ready = p.profile.iter().find(|o| o.kind == "getREADYtasks").unwrap();
        assert!(ready.service_secs / bundle > 0.40);
        // update ops ≈ 53%
        let writes: f64 =
            p.profile.iter().filter(|o| o.write).map(|o| o.service_secs).sum();
        assert!((writes / bundle - 0.546).abs() < 0.02);
    }
}
