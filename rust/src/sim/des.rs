//! The discrete-event simulator core.

use crate::sim::params::{OpSpec, SimParams};
use crate::util::rng::Rng;
use crate::Result;
use rustc_hash::FxHashMap;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Which engine architecture to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// SchalaDB/d-Chiron: workers talk to the distributed DBMS directly.
    DChiron,
    /// Original Chiron: every access hops through a single master and a
    /// centralized single-partition DBMS (Figure 6-B).
    Chiron,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub makespan_secs: f64,
    pub tasks: usize,
    /// Per worker node: sum of its DBMS access elapsed times (Experiment 5
    /// metric is the max of these).
    pub dbms_node_sums: Vec<f64>,
    pub dbms_total_secs: f64,
    /// Per access-kind elapsed totals (Experiment 6 breakdown).
    pub per_kind_secs: Vec<(String, f64)>,
    /// Total compute (task duration) consumed.
    pub compute_secs: f64,
    /// Steering queries issued (Experiment 7).
    pub steering_queries: u64,
}

impl SimReport {
    pub fn dbms_max_node_secs(&self) -> f64 {
        self.dbms_node_sums.iter().fold(0.0f64, |a, b| a.max(*b))
    }

    pub fn kind_pct(&self, kind: &str) -> f64 {
        let total: f64 = self.per_kind_secs.iter().map(|(_, s)| s).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.per_kind_secs
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, s)| 100.0 * s / total)
            .unwrap_or(0.0)
    }
}

/// Event heap entry: min-ordered by time.
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

enum EvKind {
    /// Thread (worker, thread) enters the given phase.
    Thread { worker: usize, phase: Phase },
    /// Supervisor readiness sweep.
    SupervisorScan,
    /// Steering query batch.
    Steering,
}

#[derive(Clone, Copy)]
enum Phase {
    /// Execute claim-phase op `i` of the profile; at the end of the claim
    /// ops, pop a task and run it.
    Claim(usize),
    /// Compute finished; execute finish-phase op `i`.
    Finish { op: usize, dur: f64 },
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for a min-heap on (t, seq)
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct State<'a> {
    p: &'a SimParams,
    kind: EngineKind,
    rng: Rng,
    heap: BinaryHeap<Ev>,
    seq: u64,
    /// Remaining tasks per worker's partition of the bag.
    bags: Vec<usize>,
    remaining_total: usize,
    /// One DBMS session per worker node.
    session_free: Vec<f64>,
    /// Data node core pools.
    node_cores: Vec<Vec<f64>>,
    /// Centralized pieces (Chiron).
    master_free: f64,
    central_db_free: f64,
    /// Exclusive WQ window taken by the supervisor sweep.
    scan_until: f64,
    /// Accounting.
    node_sums: Vec<f64>,
    per_kind: FxHashMap<&'static str, f64>,
    compute: f64,
    thread_end: f64,
    steering_queries: u64,
    claim_ops: Vec<OpSpec>,
    finish_ops: Vec<OpSpec>,
}

impl<'a> State<'a> {
    fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Ev { t, seq: self.seq, kind });
    }

    /// Simulate one DBMS access issued by worker `w` at time `t`; returns
    /// the completion time seen by the client.
    ///
    /// Accounting note: the recorded "time spent accessing the DBMS" runs
    /// from *session acquisition* (the paper instruments each query's
    /// elapsed time; waiting for the node's connection is client-side), so
    /// node sums stay comparable to Figure 11 while session contention
    /// still shapes the makespan.
    fn db_op(&mut self, w: usize, t: f64, op: &OpSpec) -> f64 {
        let (measured_from, end) = match self.kind {
            EngineKind::DChiron => {
                // session serialization per worker node
                let s0 = t.max(self.session_free[w]);
                // supervisor sweep holds the WQ briefly
                let s0 = if s0 < self.scan_until { self.scan_until } else { s0 };
                let n = w % self.p.data_nodes;
                // one data-node core serves the op; write service times
                // already include the synchronous backup apply (see
                // SimParams docs), so replication adds no extra core claim
                let end = {
                    let pool = &mut self.node_cores[n];
                    let (ci, _) = pool
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.total_cmp(b.1))
                        .expect("non-empty pool");
                    let start = s0.max(pool[ci]);
                    let end = start + op.service_secs;
                    pool[ci] = end;
                    end
                };
                let end = end + self.p.net_rtt_secs;
                self.session_free[w] = end;
                (s0, end)
            }
            EngineKind::Chiron => {
                // request → master queue → central DB → reply (+ack hop on
                // writes: the master must confirm)
                let s0 = t + self.p.msg_latency_secs;
                let m = s0.max(self.master_free);
                let m_end = m + self.p.master_service_secs;
                self.master_free = m_end;
                let db_start = m_end.max(self.central_db_free);
                let db_end = db_start + op.service_secs * self.p.central_db_factor;
                self.central_db_free = db_end;
                let mut end = db_end + self.p.msg_latency_secs;
                if op.write {
                    end += self.p.msg_latency_secs; // the ack the paper counts
                }
                // Chiron's figure-6B costs are exactly the point: measure
                // the whole master-mediated round trip.
                (t, end)
            }
        };
        let elapsed = end - measured_from;
        self.node_sums[w] += elapsed;
        // The per-kind breakdown (Figure 12) reflects where the DBMS spends
        // its time — service, not queueing, which is shared overhead.
        *self.per_kind.entry(op.kind).or_insert(0.0) +=
            op.service_secs + self.p.net_rtt_secs;
        end
    }

    fn wall_duration(&mut self, mean: f64) -> f64 {
        let dur = if mean > 0.0 { self.rng.task_duration(mean, 0.05) } else { 0.0 };
        let ratio = self.p.threads as f64 / self.p.cores_per_worker as f64;
        if ratio > 1.0 {
            dur * ratio * (1.0 + self.p.oversub_tax * (ratio - 1.0))
        } else {
            dur
        }
    }
}

/// Run the simulation: `n_tasks` independent tasks with the given mean
/// duration (the paper's synthetic workload model), circularly sharded over
/// the workers.
pub fn simulate(
    kind: EngineKind,
    n_tasks: usize,
    mean_task_secs: f64,
    p: &SimParams,
) -> Result<SimReport> {
    let w = p.workers.max(1);
    let mut bags = vec![n_tasks / w; w];
    for extra in bags.iter_mut().take(n_tasks % w) {
        *extra += 1;
    }
    let claim_ops: Vec<OpSpec> = p.profile.iter().filter(|o| o.claim_phase).copied().collect();
    let finish_ops: Vec<OpSpec> = p.profile.iter().filter(|o| !o.claim_phase).copied().collect();
    let mut st = State {
        p,
        kind,
        rng: Rng::new(p.seed),
        heap: BinaryHeap::new(),
        seq: 0,
        remaining_total: n_tasks,
        bags,
        session_free: vec![0.0; w],
        node_cores: vec![vec![0.0; p.cores_per_data_node]; p.data_nodes.max(1)],
        master_free: 0.0,
        central_db_free: 0.0,
        scan_until: 0.0,
        node_sums: vec![0.0; w],
        per_kind: FxHashMap::default(),
        compute: 0.0,
        thread_end: 0.0,
        steering_queries: 0,
        claim_ops,
        finish_ops,
    };

    // Seed thread events (stagger initial claims a little, as real startup
    // does).
    let mut startup = Rng::new(p.seed ^ 0xDEAD);
    for worker in 0..w {
        for _ in 0..p.threads {
            let jitter = startup.uniform(0.0, 0.010);
            st.push(jitter, EvKind::Thread { worker, phase: Phase::Claim(0) });
        }
    }
    if matches!(kind, EngineKind::DChiron) && p.sup_scan_secs_per_task > 0.0 {
        st.push(p.sup_poll_secs, EvKind::SupervisorScan);
    }
    if let Some(every) = p.steering_every_secs {
        st.push(every, EvKind::Steering);
    }

    while let Some(ev) = st.heap.pop() {
        let t = ev.t;
        match ev.kind {
            EvKind::Thread { worker, phase } => match phase {
                Phase::Claim(i) => {
                    if st.bags[worker] == 0 {
                        // partition drained; thread retires
                        st.thread_end = st.thread_end.max(t);
                        continue;
                    }
                    if i == 0 && st.remaining_total == 0 {
                        st.thread_end = st.thread_end.max(t);
                        continue;
                    }
                    let op = st.claim_ops[i];
                    let end = st.db_op(worker, t, &op);
                    if i + 1 < st.claim_ops.len() {
                        st.push(end, EvKind::Thread { worker, phase: Phase::Claim(i + 1) });
                    } else {
                        // claim complete: pop a task and compute
                        st.bags[worker] -= 1;
                        st.remaining_total -= 1;
                        let dur = st.wall_duration(mean_task_secs);
                        st.compute += dur;
                        st.push(
                            end + dur,
                            EvKind::Thread { worker, phase: Phase::Finish { op: 0, dur } },
                        );
                    }
                }
                Phase::Finish { op, dur } => {
                    let spec = st.finish_ops[op];
                    let end = st.db_op(worker, t, &spec);
                    if op + 1 < st.finish_ops.len() {
                        st.push(
                            end,
                            EvKind::Thread { worker, phase: Phase::Finish { op: op + 1, dur } },
                        );
                    } else {
                        st.thread_end = st.thread_end.max(end);
                        st.push(end, EvKind::Thread { worker, phase: Phase::Claim(0) });
                    }
                }
            },
            EvKind::SupervisorScan => {
                if st.remaining_total > 0 {
                    let dur = p.sup_scan_secs_per_task * st.remaining_total as f64;
                    st.scan_until = t + dur;
                    st.push(t + p.sup_poll_secs.max(dur), EvKind::SupervisorScan);
                }
            }
            EvKind::Steering => {
                if st.remaining_total > 0 {
                    // 7-query monitoring mix, each occupying one data-node
                    // core (they are reads; no WQ exclusion)
                    for q in 0..7usize {
                        let n = q % p.data_nodes.max(1);
                        let pool = &mut st.node_cores[n];
                        let (ci, _) = pool
                            .iter()
                            .enumerate()
                            .min_by(|a, b| a.1.total_cmp(b.1))
                            .expect("non-empty pool");
                        let start = t.max(pool[ci]);
                        pool[ci] = start + p.steering_query_secs;
                    }
                    st.steering_queries += 7;
                    st.push(
                        t + p.steering_every_secs.unwrap_or(15.0),
                        EvKind::Steering,
                    );
                }
            }
        }
    }

    let mut per_kind: Vec<(String, f64)> =
        st.per_kind.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    per_kind.sort_by(|a, b| b.1.total_cmp(&a.1));
    Ok(SimReport {
        makespan_secs: st.thread_end,
        tasks: n_tasks,
        dbms_total_secs: st.node_sums.iter().sum(),
        dbms_node_sums: st.node_sums,
        per_kind_secs: per_kind,
        compute_secs: st.compute,
        steering_queries: st.steering_queries,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(cores: usize, threads: usize) -> SimParams {
        SimParams::default().with_cores(cores, threads)
    }

    #[test]
    fn long_tasks_scale_nearly_linearly() {
        // Experiment-1 shape: doubling cores ~halves makespan for 60 s tasks
        let m120 = simulate(EngineKind::DChiron, 13_000, 60.0, &params(120, 24))
            .unwrap()
            .makespan_secs;
        let m960 = simulate(EngineKind::DChiron, 13_000, 60.0, &params(960, 24))
            .unwrap()
            .makespan_secs;
        let speedup = m120 / m960;
        assert!(
            (5.0..9.5).contains(&speedup),
            "8x cores gave {speedup:.2}x speedup (m120={m120:.0}s m960={m960:.0}s)"
        );
    }

    #[test]
    fn short_tasks_are_dbms_bound_long_tasks_are_not() {
        // Experiment-5 shape
        let p = params(936, 24);
        let short = simulate(EngineKind::DChiron, 23_400, 1.0, &p).unwrap();
        let long = simulate(EngineKind::DChiron, 23_400, 60.0, &p).unwrap();
        let short_ratio = short.dbms_max_node_secs() / short.makespan_secs;
        let long_ratio = long.dbms_max_node_secs() / long.makespan_secs;
        assert!(short_ratio > 0.7, "1s tasks should be DBMS-dominated: {short_ratio:.2}");
        assert!(long_ratio < 0.5, "60s tasks should not be: {long_ratio:.2}");
        // flat region: DBMS time roughly duration-independent for >= 5s
        let five = simulate(EngineKind::DChiron, 23_400, 5.0, &p).unwrap();
        let r = five.dbms_max_node_secs() / long.dbms_max_node_secs();
        assert!((0.5..2.0).contains(&r), "flat-region drifted: {r:.2}");
    }

    #[test]
    fn figure12_breakdown_shape() {
        let p = params(936, 24);
        let r = simulate(EngineKind::DChiron, 23_400, 10.0, &p).unwrap();
        let ready = r.kind_pct("getREADYtasks");
        assert!(ready > 35.0, "getREADYtasks share {ready:.1}%");
        let updates: f64 = ["updateToRUNNING", "updateToFINISHED", "insertDomainData", "insertProvenance"]
            .iter()
            .map(|k| r.kind_pct(k))
            .sum();
        assert!(updates > 45.0, "update share {updates:.1}%");
    }

    #[test]
    fn chiron_is_flat_and_much_slower_on_short_tasks() {
        // Experiment-8 shape
        let p = params(936, 24);
        let d_short = simulate(EngineKind::DChiron, 20_000, 1.0, &p).unwrap().makespan_secs;
        let c_short = simulate(EngineKind::Chiron, 20_000, 1.0, &p).unwrap().makespan_secs;
        let c_long = simulate(EngineKind::Chiron, 20_000, 16.0, &p).unwrap().makespan_secs;
        assert!(
            c_short / d_short > 5.0,
            "Chiron should be many times slower: {c_short:.0} vs {d_short:.0}"
        );
        // Chiron insensitive to duration (its bottleneck is the master+DB)
        let flatness = c_long / c_short;
        assert!(flatness < 1.6, "Chiron should be flat-ish: {flatness:.2}");
    }

    #[test]
    fn steering_overhead_is_negligible() {
        // Experiment-7 shape
        let base = simulate(EngineKind::DChiron, 23_400, 5.0, &params(936, 24)).unwrap();
        let mut p = params(936, 24);
        p.steering_every_secs = Some(15.0);
        let steered = simulate(EngineKind::DChiron, 23_400, 5.0, &p).unwrap();
        assert!(steered.steering_queries > 0);
        let overhead = steered.makespan_secs / base.makespan_secs - 1.0;
        assert!(overhead < 0.05, "steering overhead {:.1}%", overhead * 100.0);
    }

    #[test]
    fn determinism() {
        let p = params(240, 24);
        let a = simulate(EngineKind::DChiron, 6_000, 60.0, &p).unwrap();
        let b = simulate(EngineKind::DChiron, 6_000, 60.0, &p).unwrap();
        assert_eq!(a.makespan_secs, b.makespan_secs);
        assert_eq!(a.dbms_total_secs, b.dbms_total_secs);
    }

    #[test]
    fn oversubscription_taxes_48_threads() {
        let m24 = simulate(EngineKind::DChiron, 13_000, 60.0, &params(960, 24))
            .unwrap()
            .makespan_secs;
        let m48 = simulate(EngineKind::DChiron, 13_000, 60.0, &params(960, 48))
            .unwrap()
            .makespan_secs;
        // 48 threads on 24 cores: no throughput win, a visible tax
        assert!(m48 > m24 * 1.02, "expected oversubscription tax: {m24:.0} vs {m48:.0}");
    }
}
