//! Discrete-event simulation of the paper's Grid5000 testbed.
//!
//! We cannot allocate 42 nodes × 24 cores, so Experiments 1–8 (the figures
//! that sweep up to 960 cores) run on a calibrated discrete-event model of
//! the deployment; everything else in this repository (correctness,
//! steering, failover, provenance) runs for real against the actual engine.
//!
//! What is modeled (see DESIGN.md §Substitutions):
//! - worker nodes with `cores` CPUs running `threads` claim→execute→report
//!   loops; oversubscription (threads > cores) stretches compute and adds a
//!   context-switching tax;
//! - the paper's per-worker WQ partition: one DBMS session per worker node,
//!   ops serialized per partition, writes also applied to the backup
//!   replica; data nodes have finite CPU;
//! - the supervisor's periodic readiness scan, whose cost grows with the
//!   task count — the term that produces the paper's weak-scaling
//!   inflation;
//! - centralized Chiron: every request hops through a single master with an
//!   auxiliary queue and an extra completion acknowledgement, against a
//!   single-partition DBMS (Figure 6-B).
//!
//! Calibration: service-time constants are anchored to the paper's own
//! observable anchor points (Experiment 5: DBMS time ≈ total time for ≤3 s
//! tasks, flat DBMS time for ≥5 s tasks, break-even at ≈25 s; Experiment 8:
//! d-Chiron ≈ 91% faster at 20k×1 s), not to our in-process engine, which
//! is orders of magnitude faster than a 2016-era networked MySQL Cluster.
//! `storage_micro` benches document the real engine's latencies separately.

pub mod des;
pub mod experiments;
pub mod params;

pub use des::{simulate, EngineKind, SimReport};
pub use params::SimParams;
