//! Experiment harnesses: one function per paper table/figure, each
//! regenerating the corresponding rows/series. Shared by the `exp*` bench
//! binaries and the `dchiron bench-sim` CLI.

use crate::sim::des::{simulate, EngineKind};
use crate::sim::params::SimParams;
use crate::util::json::Json;
use crate::util::{fmt_secs, render_table};
use crate::Result;

/// A rendered experiment: title, aligned text table, machine-readable JSON.
pub struct ExperimentOutput {
    pub id: &'static str,
    pub title: &'static str,
    pub table: String,
    pub json: Json,
}

impl ExperimentOutput {
    pub fn print(&self) {
        println!("== {} — {} ==", self.id, self.title);
        println!("{}", self.table);
    }
}

fn mk(id: &'static str, title: &'static str, header: &[&str], rows: Vec<Vec<String>>, json: Json) -> ExperimentOutput {
    ExperimentOutput { id, title, table: render_table(header, &rows), json }
}

/// Experiment 1 / Figure 9(a): strong scaling, 13k tasks @ 60 s, cores in
/// {120, 240, 480, 960} × threads {12, 24, 48}; linear reference from the
/// 120-core base.
pub fn exp1_strong_scaling() -> Result<ExperimentOutput> {
    let tasks = 13_000;
    let dur = 60.0;
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for threads in [12usize, 24, 48] {
        let base = simulate(
            EngineKind::DChiron,
            tasks,
            dur,
            &SimParams::default().with_cores(120, threads),
        )?
        .makespan_secs;
        for cores in [120usize, 240, 480, 960] {
            let p = SimParams::default().with_cores(cores, threads);
            let r = simulate(EngineKind::DChiron, tasks, dur, &p)?;
            let linear = base * 120.0 / cores as f64;
            let eff = linear / r.makespan_secs;
            rows.push(vec![
                cores.to_string(),
                threads.to_string(),
                fmt_secs(r.makespan_secs),
                fmt_secs(linear),
                format!("{:.2}", eff),
            ]);
            series.push(
                Json::obj()
                    .set("cores", cores)
                    .set("threads", threads)
                    .set("makespan_secs", r.makespan_secs)
                    .set("linear_secs", linear)
                    .set("efficiency", eff),
            );
        }
    }
    Ok(mk(
        "exp1",
        "strong scaling (Fig 9a): 13k tasks @ 60s",
        &["cores", "threads", "makespan", "linear", "efficiency"],
        rows,
        Json::obj().set("experiment", "exp1").set("series", Json::Arr(series)),
    ))
}

/// Experiment 2 / Figure 9(b): weak scaling — 6k/12k/23.4k tasks @ 60 s on
/// 240/480/936 cores, 24 threads.
pub fn exp2_weak_scaling() -> Result<ExperimentOutput> {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut base = None;
    for (cores, tasks) in [(240usize, 6_000usize), (480, 12_000), (936, 23_400)] {
        let p = SimParams::default().with_cores(cores, 24);
        let r = simulate(EngineKind::DChiron, tasks, 60.0, &p)?;
        let b = *base.get_or_insert(r.makespan_secs);
        rows.push(vec![
            cores.to_string(),
            tasks.to_string(),
            format!("{:.1}min", r.makespan_secs / 60.0),
            format!("{:.1}min", b / 60.0),
            format!("{:+.1}%", 100.0 * (r.makespan_secs / b - 1.0)),
        ]);
        series.push(
            Json::obj()
                .set("cores", cores)
                .set("tasks", tasks)
                .set("makespan_secs", r.makespan_secs)
                .set("inflation_pct", 100.0 * (r.makespan_secs / b - 1.0)),
        );
    }
    Ok(mk(
        "exp2",
        "weak scaling (Fig 9b): tasks grow with cores @ 60s",
        &["cores", "tasks", "makespan", "ideal", "inflation"],
        rows,
        Json::obj().set("experiment", "exp2").set("series", Json::Arr(series)),
    ))
}

/// Experiment 3 / Figure 10(a): fixed duration {5 s, 60 s}, tasks in
/// {4.6k, 12k, 23.4k}, 936 cores; linear reference from the smallest count.
pub fn exp3_tasks_scaling() -> Result<ExperimentOutput> {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for dur in [5.0f64, 60.0] {
        let mut base: Option<(usize, f64)> = None;
        for tasks in [4_600usize, 12_000, 23_400] {
            let p = SimParams::default().with_cores(936, 24);
            let r = simulate(EngineKind::DChiron, tasks, dur, &p)?;
            let (bt, bm) = *base.get_or_insert((tasks, r.makespan_secs));
            let linear = bm * tasks as f64 / bt as f64;
            let away = 100.0 * (r.makespan_secs / linear - 1.0);
            rows.push(vec![
                format!("{dur}s"),
                tasks.to_string(),
                fmt_secs(r.makespan_secs),
                fmt_secs(linear),
                format!("{away:+.1}%"),
            ]);
            series.push(
                Json::obj()
                    .set("duration_secs", dur)
                    .set("tasks", tasks)
                    .set("makespan_secs", r.makespan_secs)
                    .set("pct_from_linear", away),
            );
        }
    }
    Ok(mk(
        "exp3",
        "workload scaling by task count (Fig 10a), 936 cores",
        &["duration", "tasks", "makespan", "linear", "from linear"],
        rows,
        Json::obj().set("experiment", "exp3").set("series", Json::Arr(series)),
    ))
}

/// Experiment 4 / Figure 10(b): fixed task counts {4.6k, 23.4k}, duration
/// sweep {5..120 s}, 936 cores; linear reference anchored at 120 s.
pub fn exp4_duration_scaling() -> Result<ExperimentOutput> {
    let durations = [5.0f64, 15.0, 30.0, 60.0, 120.0];
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for tasks in [4_600usize, 23_400] {
        let p = SimParams::default().with_cores(936, 24);
        let base = simulate(EngineKind::DChiron, tasks, 120.0, &p)?.makespan_secs;
        for dur in durations {
            let r = simulate(EngineKind::DChiron, tasks, dur, &p)?;
            let linear = base * dur / 120.0;
            let away = 100.0 * (r.makespan_secs / linear - 1.0);
            rows.push(vec![
                tasks.to_string(),
                format!("{dur}s"),
                fmt_secs(r.makespan_secs),
                fmt_secs(linear),
                format!("{away:+.1}%"),
            ]);
            series.push(
                Json::obj()
                    .set("tasks", tasks)
                    .set("duration_secs", dur)
                    .set("makespan_secs", r.makespan_secs)
                    .set("pct_from_linear", away),
            );
        }
    }
    Ok(mk(
        "exp4",
        "workload scaling by duration (Fig 10b), 936 cores",
        &["tasks", "duration", "makespan", "linear", "from linear"],
        rows,
        Json::obj().set("experiment", "exp4").set("series", Json::Arr(series)),
    ))
}

/// Experiment 5 / Figure 11: DBMS access time vs total time, 23.4k tasks,
/// durations {1..60 s}, 936 cores.
pub fn exp5_dbms_impact() -> Result<ExperimentOutput> {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for dur in [1.0f64, 2.0, 3.0, 4.0, 5.0, 10.0, 30.0, 60.0] {
        let p = SimParams::default().with_cores(936, 24);
        let r = simulate(EngineKind::DChiron, 23_400, dur, &p)?;
        let dbms = r.dbms_max_node_secs();
        rows.push(vec![
            format!("{dur}s"),
            fmt_secs(r.makespan_secs),
            fmt_secs(dbms),
            format!("{:.0}%", 100.0 * dbms / r.makespan_secs),
        ]);
        series.push(
            Json::obj()
                .set("duration_secs", dur)
                .set("total_secs", r.makespan_secs)
                .set("dbms_secs", dbms)
                .set("dbms_share_pct", 100.0 * dbms / r.makespan_secs),
        );
    }
    Ok(mk(
        "exp5",
        "DBMS access time vs total (Fig 11): 23.4k tasks, 936 cores",
        &["mean duration", "total", "DBMS (max node)", "share"],
        rows,
        Json::obj().set("experiment", "exp5").set("series", Json::Arr(series)),
    ))
}

/// Experiment 6 / Figure 12: per-query-kind share of DBMS time, 23.4k tasks
/// @ 10 s, 936 cores.
pub fn exp6_query_breakdown() -> Result<ExperimentOutput> {
    let p = SimParams::default().with_cores(936, 24);
    let r = simulate(EngineKind::DChiron, 23_400, 10.0, &p)?;
    let mut rows = Vec::new();
    let mut obj = Json::obj().set("experiment", "exp6");
    for (kind, secs) in &r.per_kind_secs {
        let pct = r.kind_pct(kind);
        rows.push(vec![kind.clone(), fmt_secs(*secs), format!("{pct:.1}%")]);
        obj = obj.set(kind, pct);
    }
    Ok(mk(
        "exp6",
        "DBMS access breakdown (Fig 12): 23.4k tasks @ 10s",
        &["access", "total", "share"],
        rows,
        obj,
    ))
}

/// Experiment 7 / Figure 13: steering-query overhead — 23.4k tasks @ 5 s
/// with and without the Q1–Q7 monitoring mix every 15 s.
pub fn exp7_steering_overhead() -> Result<ExperimentOutput> {
    let base_p = SimParams::default().with_cores(936, 24);
    let base = simulate(EngineKind::DChiron, 23_400, 5.0, &base_p)?;
    let mut steer_p = base_p.clone();
    steer_p.steering_every_secs = Some(15.0);
    let steered = simulate(EngineKind::DChiron, 23_400, 5.0, &steer_p)?;
    let overhead = 100.0 * (steered.makespan_secs / base.makespan_secs - 1.0);
    let rows = vec![
        vec!["without queries".into(), fmt_secs(base.makespan_secs), "-".into()],
        vec![
            "with queries @15s".into(),
            fmt_secs(steered.makespan_secs),
            format!("{overhead:+.2}%"),
        ],
    ];
    Ok(mk(
        "exp7",
        "steering overhead (Fig 13): 23.4k tasks @ 5s",
        &["scenario", "makespan", "overhead"],
        rows,
        Json::obj()
            .set("experiment", "exp7")
            .set("base_secs", base.makespan_secs)
            .set("steered_secs", steered.makespan_secs)
            .set("overhead_pct", overhead)
            .set("queries", steered.steering_queries as i64),
    ))
}

/// Experiment 8 / Figure 14: Chiron vs d-Chiron on {5k, 20k} tasks ×
/// {1 s, 16 s}, 936 cores.
pub fn exp8_chiron_vs_dchiron() -> Result<ExperimentOutput> {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for (label, tasks, dur) in [
        ("(a) 5k x 1s", 5_000usize, 1.0f64),
        ("(b) 5k x 16s", 5_000, 16.0),
        ("(c) 20k x 1s", 20_000, 1.0),
        ("(d) 20k x 16s", 20_000, 16.0),
    ] {
        let p = SimParams::default().with_cores(936, 24);
        let d = simulate(EngineKind::DChiron, tasks, dur, &p)?.makespan_secs;
        let c = simulate(EngineKind::Chiron, tasks, dur, &p)?.makespan_secs;
        rows.push(vec![
            label.to_string(),
            fmt_secs(d),
            fmt_secs(c),
            format!("{:.1}x", c / d),
            format!("{:.0}%", 100.0 * (1.0 - d / c)),
        ]);
        series.push(
            Json::obj()
                .set("workload", label)
                .set("dchiron_secs", d)
                .set("chiron_secs", c)
                .set("speedup", c / d),
        );
    }
    Ok(mk(
        "exp8",
        "Chiron vs d-Chiron (Fig 14), 936 cores",
        &["workload", "d-Chiron", "Chiron", "speedup", "faster by"],
        rows,
        Json::obj().set("experiment", "exp8").set("series", Json::Arr(series)),
    ))
}

/// All experiments in paper order.
pub fn all() -> Vec<fn() -> Result<ExperimentOutput>> {
    vec![
        exp1_strong_scaling,
        exp2_weak_scaling,
        exp3_tasks_scaling,
        exp4_duration_scaling,
        exp5_dbms_impact,
        exp6_query_breakdown,
        exp7_steering_overhead,
        exp8_chiron_vs_dchiron,
    ]
}

/// Run one experiment by id.
pub fn run(id: &str) -> Result<ExperimentOutput> {
    match id {
        "exp1" => exp1_strong_scaling(),
        "exp2" => exp2_weak_scaling(),
        "exp3" => exp3_tasks_scaling(),
        "exp4" => exp4_duration_scaling(),
        "exp5" => exp5_dbms_impact(),
        "exp6" => exp6_query_breakdown(),
        "exp7" => exp7_steering_overhead(),
        "exp8" => exp8_chiron_vs_dchiron(),
        other => Err(crate::Error::Engine(format!("unknown experiment '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_experiment_produces_rows_and_json() {
        for f in all() {
            let out = f().unwrap();
            assert!(out.table.lines().count() >= 3, "{} table too small", out.id);
            let js = out.json.to_string();
            assert!(js.contains("experiment"), "{} json missing tag", out.id);
        }
    }

    #[test]
    fn run_by_id_and_unknown() {
        assert!(run("exp5").is_ok());
        assert!(run("nope").is_err());
    }
}
