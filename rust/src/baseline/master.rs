//! The Chiron master and its message-passing protocol.

use crate::coordinator::engine::RunReport;
use crate::coordinator::payload::{self, Payload, RunnerRegistry, TaskCtx};
use crate::coordinator::supervisor::{IdGen, Supervisor};
use crate::coordinator::workflow::WorkflowSpec;
use crate::coordinator::{schema, status};
use crate::storage::cluster::ClusterConfig;
use crate::storage::prepared::Prepared;
use crate::storage::{AccessKind, DbCluster, Value};
use crate::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// A task assignment shipped from master to worker.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub taskid: i64,
    pub actid: i64,
    pub duration: f64,
    pub inputs: Vec<(String, f64)>,
}

/// Worker → master messages ("MPI" in the paper).
enum Msg {
    /// Figure 6-B step 1: worker asks the master for work.
    GetTask { worker: u32, reply: Sender<Option<Assignment>> },
    /// Step 5: worker reports completion; master must acknowledge (step 8).
    TaskDone {
        worker: u32,
        taskid: i64,
        actid: i64,
        out_fields: Vec<(String, f64)>,
        out_files: Vec<(String, i64)>,
        stdout: String,
        ack: Sender<()>,
    },
}

/// Chiron deployment parameters.
#[derive(Clone)]
pub struct ChironConfig {
    pub workers: usize,
    pub threads_per_worker: usize,
    pub time_scale: f64,
    /// Simulated per-message latency of the MPI fabric, in seconds (applied
    /// once per message; 0.0 for in-process tests).
    pub msg_latency_secs: f64,
    pub supervisor_poll_secs: f64,
    pub seed: u64,
}

impl Default for ChironConfig {
    fn default() -> Self {
        ChironConfig {
            workers: 2,
            threads_per_worker: 2,
            time_scale: 1.0,
            msg_latency_secs: 0.0,
            supervisor_poll_secs: 0.002,
            seed: 42,
        }
    }
}

/// Centralized Chiron engine (API-compatible with `DChironEngine::run`).
pub struct ChironEngine {
    pub config: ChironConfig,
    pub registry: Arc<RunnerRegistry>,
}

impl ChironEngine {
    pub fn new(config: ChironConfig) -> ChironEngine {
        ChironEngine { config, registry: Arc::new(RunnerRegistry::new()) }
    }

    /// Run a workflow to completion under centralized control.
    pub fn run(&self, wf: WorkflowSpec, inputs: Vec<Vec<(String, f64)>>) -> Result<RunReport> {
        wf.validate()?;
        let cfg = self.config.clone();

        // Centralized DBMS: one data node, no replication, one partition per
        // table (create_schema with workers=1 collapses all partitioning).
        let db = DbCluster::start(
            ClusterConfig::builder().data_nodes(1).replication(false).build()?,
        )?;
        schema::create_schema(&db, 1)?;
        schema::register_nodes(&db, cfg.workers, cfg.threads_per_worker)?;

        let ids = Arc::new(IdGen::default());
        ids.task.store(1, Ordering::Relaxed);
        ids.field.store(1, Ordering::Relaxed);
        ids.file.store(1, Ordering::Relaxed);
        ids.prov.store(1, Ordering::Relaxed);
        ids.dep.store(1, Ordering::Relaxed);

        // In centralized Chiron the supervisor/readiness role is part of the
        // master; note workers=1 here because the WQ is not worker-sharded —
        // the master hands tasks to whichever worker asks.
        let mut sup = Supervisor::new(db.clone(), wf.clone(), 1, ids.clone(), cfg.seed);
        let done = Arc::new(AtomicBool::new(false));
        sup.done = done.clone();
        sup.bootstrap(&inputs)?;
        let total_tasks = wf.planned_total_tasks();

        let (tx, rx) = channel::<Msg>();
        let payloads: Arc<Vec<Payload>> =
            Arc::new(wf.activities.iter().map(|a| a.payload.clone()).collect());

        let t0 = Instant::now();
        let executed = Arc::new(AtomicU64::new(0));
        let failures = Arc::new(AtomicU64::new(0));

        // Master thread: the only DB client.
        let master = {
            let db = db.clone();
            let done = done.clone();
            let ids = ids.clone();
            let poll = cfg.supervisor_poll_secs;
            let latency = cfg.msg_latency_secs;
            std::thread::Builder::new()
                .name("chiron-master".into())
                .spawn(move || {
                    master_loop(sup, db, rx, done, ids, poll, latency);
                })
                .expect("spawn master")
        };

        // Worker threads: message passing only, never touch the DB.
        let mut handles = vec![master];
        for w in 0..cfg.workers as u32 {
            for t in 0..cfg.threads_per_worker {
                let tx = tx.clone();
                let payloads = payloads.clone();
                let registry = self.registry.clone();
                let done = done.clone();
                let executed = executed.clone();
                let failures = failures.clone();
                let cfg = cfg.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("chiron-w{w}-t{t}"))
                        .spawn(move || {
                            worker_loop(
                                w, tx, payloads, registry, done, executed, failures, &cfg,
                            );
                        })
                        .expect("spawn chiron worker"),
                );
            }
        }
        drop(tx);
        for h in handles {
            h.join().map_err(|_| crate::Error::Engine("chiron thread panicked".into()))?;
        }

        Ok(RunReport {
            makespan_secs: t0.elapsed().as_secs_f64(),
            total_tasks,
            executed_tasks: executed.load(Ordering::Relaxed),
            failed_tasks: failures.load(Ordering::Relaxed),
            claim_races_lost: 0,
            dbms_total_secs: db.stats.total_secs(),
            dbms_max_node_secs: db.stats.max_node_secs(),
            access_stats: db.stats.snapshot(),
            db_bytes: db.total_bytes(),
            supervisor_failovers: 0,
        })
    }
}

/// The master's per-message statement set, prepared once against the
/// centralized DB (values bound per message; the master is the only DB
/// client, so these cover every statement on the Figure 6-B path).
struct MasterStmts {
    claim: Prepared,
    get_inputs: Prepared,
    insert_field: Prepared,
    insert_file: Prepared,
    finish: Prepared,
}

impl MasterStmts {
    fn prepare(db: &DbCluster) -> Result<MasterStmts> {
        Ok(MasterStmts {
            claim: db.prepare(
                "UPDATE workqueue SET status = 'RUNNING', starttime = NOW(), coreid = ? \
                 WHERE status = 'READY' \
                 ORDER BY taskid LIMIT 1 RETURNING taskid, actid, duration",
            )?,
            get_inputs: db.prepare(
                "SELECT field, value FROM taskfield WHERE taskid = ? AND direction = 'in'",
            )?,
            insert_field: db.prepare(
                "INSERT INTO taskfield (fieldid, taskid, actid, field, value, direction) \
                 VALUES (?, ?, ?, ?, ?, 'out')",
            )?,
            insert_file: db.prepare(
                "INSERT INTO file (fileid, taskid, path, size_bytes, direction) \
                 VALUES (?, ?, ?, ?, 'out')",
            )?,
            finish: db.prepare(
                "UPDATE workqueue SET status = 'FINISHED', endtime = NOW(), stdout = ? \
                 WHERE taskid = ?",
            )?,
        })
    }
}

/// Master event loop: drain the auxiliary request queue, touch the DB on the
/// workers' behalf, run readiness polls.
fn master_loop(
    mut sup: Supervisor,
    db: Arc<DbCluster>,
    rx: Receiver<Msg>,
    done: Arc<AtomicBool>,
    ids: Arc<IdGen>,
    poll_secs: f64,
    latency: f64,
) {
    // The schema exists before the master thread starts, and the statement
    // texts are static, so preparation cannot fail outside of programmer
    // error — surface that loudly.
    let stmts = MasterStmts::prepare(&db).expect("prepare master statements");
    let mut last_poll = Instant::now();
    loop {
        if done.load(Ordering::SeqCst) {
            // drain any straggler messages so workers don't block on replies
            while let Ok(msg) = rx.try_recv() {
                answer(&db, &ids, &stmts, msg, latency, true);
            }
            return;
        }
        // auxiliary queue: serve at most a small batch, then poll readiness
        match rx.recv_timeout(std::time::Duration::from_secs_f64(poll_secs)) {
            Ok(msg) => answer(&db, &ids, &stmts, msg, latency, false),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
        if last_poll.elapsed().as_secs_f64() >= poll_secs {
            if let Ok(r) = sup.poll() {
                if r.workflow_done {
                    // drain remaining requests with "no task"
                    while let Ok(msg) = rx.try_recv() {
                        answer(&db, &ids, &stmts, msg, latency, true);
                    }
                    return;
                }
            }
            last_poll = Instant::now();
        }
    }
}

/// Serve one worker message against the centralized DB.
fn answer(
    db: &DbCluster,
    ids: &IdGen,
    stmts: &MasterStmts,
    msg: Msg,
    latency: f64,
    draining: bool,
) {
    if latency > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(latency));
    }
    match msg {
        Msg::GetTask { worker, reply } => {
            if draining {
                let _ = reply.send(None);
                return;
            }
            // master claims a task on the worker's behalf (steps 2-3)
            let claimed = db
                .exec_prepared(
                    worker,
                    AccessKind::GetReadyTasks,
                    &stmts.claim,
                    &[Value::Int(worker as i64)],
                )
                .map(|r| r.rows());
            let assignment = match claimed {
                Ok(rs) if !rs.rows.is_empty() => {
                    let taskid = rs.rows[0].values[0].as_i64().unwrap();
                    let actid = rs.rows[0].values[1].as_i64().unwrap();
                    let duration = rs.rows[0].values[2].as_f64().unwrap_or(0.0);
                    let inputs = db
                        .exec_prepared(
                            worker,
                            AccessKind::GetFileFields,
                            &stmts.get_inputs,
                            &[Value::Int(taskid)],
                        )
                        .map(|r| r.rows())
                        .map(|rs| {
                            rs.rows
                                .iter()
                                .map(|r| {
                                    (
                                        r.values[0].as_str().unwrap_or("").to_string(),
                                        r.values[1].as_f64().unwrap_or(0.0),
                                    )
                                })
                                .collect()
                        })
                        .unwrap_or_default();
                    Some(Assignment { taskid, actid, duration, inputs })
                }
                _ => None,
            };
            let _ = reply.send(assignment);
        }
        Msg::TaskDone { worker, taskid, actid, out_fields, out_files, stdout, ack } => {
            // steps 6-7: master records outputs + completion
            if !out_fields.is_empty() {
                let rows: Vec<Vec<Value>> = out_fields
                    .iter()
                    .map(|(f, v)| {
                        let fid = IdGen::next(&ids.field);
                        vec![
                            Value::Int(fid),
                            Value::Int(taskid),
                            Value::Int(actid),
                            Value::str(f),
                            Value::Float(*v),
                        ]
                    })
                    .collect();
                let _ = db.exec_prepared_batch(
                    worker,
                    AccessKind::InsertDomainData,
                    &stmts.insert_field,
                    &rows,
                );
            }
            if !out_files.is_empty() {
                let rows: Vec<Vec<Value>> = out_files
                    .iter()
                    .map(|(p, sz)| {
                        let fid = IdGen::next(&ids.file);
                        vec![Value::Int(fid), Value::Int(taskid), Value::str(p), Value::Int(*sz)]
                    })
                    .collect();
                let _ = db.exec_prepared_batch(
                    worker,
                    AccessKind::InsertDomainData,
                    &stmts.insert_file,
                    &rows,
                );
            }
            let _ = db.exec_prepared(
                worker,
                AccessKind::UpdateToFinished,
                &stmts.finish,
                &[Value::str(&stdout), Value::Int(taskid)],
            );
            // step 8: the extra acknowledgement the paper calls out
            let _ = ack.send(());
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker: u32,
    tx: Sender<Msg>,
    payloads: Arc<Vec<Payload>>,
    registry: Arc<RunnerRegistry>,
    done: Arc<AtomicBool>,
    executed: Arc<AtomicU64>,
    failures: Arc<AtomicU64>,
    cfg: &ChironConfig,
) {
    while !done.load(Ordering::SeqCst) {
        let (reply_tx, reply_rx) = channel();
        if tx.send(Msg::GetTask { worker, reply: reply_tx }).is_err() {
            return;
        }
        let assignment = match reply_rx.recv() {
            Ok(a) => a,
            Err(_) => return,
        };
        let Some(a) = assignment else {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                (cfg.supervisor_poll_secs / 2.0).max(0.0005),
            ));
            continue;
        };
        let payload = match payloads.get((a.actid - 1) as usize) {
            Some(p) => p.clone(),
            None => continue,
        };
        let ctx = TaskCtx {
            taskid: a.taskid,
            actid: a.actid,
            workerid: worker as i64,
            inputs: a.inputs.clone(),
            seed: cfg.seed ^ (a.taskid as u64).wrapping_mul(0x9E3779B97F4A7C15),
            duration: a.duration,
            time_scale: cfg.time_scale,
        };
        match payload::execute(&payload, &ctx, &registry) {
            Ok(out) => {
                executed.fetch_add(1, Ordering::Relaxed);
                let (ack_tx, ack_rx) = channel();
                if tx
                    .send(Msg::TaskDone {
                        worker,
                        taskid: a.taskid,
                        actid: a.actid,
                        out_fields: out.fields,
                        out_files: out.files,
                        stdout: out.stdout,
                        ack: ack_tx,
                    })
                    .is_err()
                {
                    return;
                }
                let _ = ack_rx.recv(); // wait for the master's ack
            }
            Err(_) => {
                failures.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

// `status` is referenced in module docs.
#[allow(unused_imports)]
use status as _status_doc;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::SyntheticKind;
    use crate::coordinator::workflow::{ActivitySpec, Operator};

    #[test]
    fn centralized_run_completes_small_workflow() {
        let wf = WorkflowSpec::new("c", 16)
            .activity(ActivitySpec::new("a1", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
            .activity(ActivitySpec::new("a2", Operator::Map, Payload::Sleep { mean_secs: 1.0 }));
        let engine = ChironEngine::new(ChironConfig {
            workers: 2,
            threads_per_worker: 2,
            time_scale: 0.001,
            ..Default::default()
        });
        let report = engine.run(wf, vec![vec![]; 16]).unwrap();
        assert_eq!(report.executed_tasks, 32);
        assert_eq!(report.failed_tasks, 0);
    }

    #[test]
    fn centralized_preserves_domain_dataflow() {
        let wf = WorkflowSpec::new("c2", 6).activity(
            ActivitySpec::new(
                "sweep",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::Quadratic },
            )
            .with_fields(&["x", "y"]),
        );
        let engine = ChironEngine::new(ChironConfig {
            workers: 2,
            threads_per_worker: 1,
            time_scale: 0.0,
            ..Default::default()
        });
        let report = engine.run(wf, vec![vec![("a".into(), 1.0)]; 6]).unwrap();
        assert_eq!(report.executed_tasks, 6);
        assert!(report.db_bytes > 0);
        // master did all DB work: GetReadyTasks was tagged per requesting
        // worker but executed centrally; there must be claim traffic
        assert!(report
            .access_stats
            .iter()
            .any(|(k, s)| *k == AccessKind::GetReadyTasks && s.count >= 6));
    }

    /// The architectural point of Experiment 8: with many workers hammering
    /// short tasks, d-Chiron outperforms the centralized master. At unit-test
    /// scale we only assert both complete and produce identical task counts.
    #[test]
    fn chiron_and_dchiron_agree_on_results() {
        use crate::coordinator::engine::{DChironEngine, EngineConfig};
        let wf = || {
            WorkflowSpec::new("agree", 10).activity(
                ActivitySpec::new(
                    "sweep",
                    Operator::Map,
                    Payload::Synthetic { kind: SyntheticKind::Quadratic },
                )
                .with_fields(&["x", "y"]),
            )
        };
        let inputs: Vec<Vec<(String, f64)>> = (0..10)
            .map(|i| vec![("a".into(), 1.0), ("b".into(), i as f64), ("c".into(), 2.0)])
            .collect();
        let c = ChironEngine::new(ChironConfig { time_scale: 0.0, ..Default::default() })
            .run(wf(), inputs.clone())
            .unwrap();
        let d = DChironEngine::new(EngineConfig {
            time_scale: 0.0,
            supervisor_poll_secs: 0.001,
            ..Default::default()
        })
        .run(wf(), inputs)
        .unwrap();
        assert_eq!(c.executed_tasks, d.executed_tasks);
    }
}
