//! Centralized Chiron: the Experiment-8 baseline.
//!
//! Original Chiron's execution control (paper Figure 4 / Figure 6-B): a
//! single *master* node is the only DBMS client. Workers ask the master for
//! tasks over message passing (MPI in the paper; typed channels here, same
//! control-flow shape), the master queues those requests, serves them one at
//! a time against a *centralized* DBMS (one data node, no replication, one
//! partition per table), and requires an extra acknowledgement hop when a
//! worker reports completion. Every proxy step the paper counts in Figure
//! 6-B exists here: request → master queue → DB → reply → execute → report →
//! DB → ack.

pub mod master;

pub use master::{ChironConfig, ChironEngine};
