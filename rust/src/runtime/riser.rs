//! Task runners backed by the AOT-compiled riser fatigue artifacts.
//!
//! `riser_stress`: environmental conditions → curvature components
//! (cx, cy, cz) + accumulated modal damage — the Pallas kernel lives inside
//! this artifact. `riser_wear`: curvature → wear factor f1.
//!
//! The artifacts are compiled for a fixed batch `BATCH`; a task carries one
//! condition, so the runner broadcasts it across the batch and reads row 0
//! (the batch dimension exists to keep the kernel MXU-shaped, and lets a
//! future batching scheduler amortize calls).

use crate::coordinator::payload::{TaskCtx, TaskOutput, TaskRunner};
use crate::runtime::{PjrtService, Tensor};
use crate::{Error, Result};

/// Batch size the artifacts were lowered with (must match
/// `python/compile/model.py::BATCH`).
pub const BATCH: usize = 64;

/// Stress-analysis runner: inputs `wind`, `wave`, `depth` → outputs
/// `cx`, `cy`, `cz` (+ a raw stress file pointer).
pub struct RiserStressRunner {
    svc: PjrtService,
}

impl RiserStressRunner {
    pub fn new(svc: PjrtService) -> RiserStressRunner {
        RiserStressRunner { svc }
    }
}

fn broadcast_env(ctx: &TaskCtx, fields: [&str; 3]) -> Result<Tensor> {
    let mut vals = [0.0f32; 3];
    for (i, f) in fields.iter().enumerate() {
        vals[i] = ctx
            .input(f)
            .ok_or_else(|| Error::Engine(format!("task {} missing input '{f}'", ctx.taskid)))?
            as f32;
    }
    let mut data = Vec::with_capacity(BATCH * 3);
    for _ in 0..BATCH {
        data.extend_from_slice(&vals);
    }
    Ok(Tensor::new(data, vec![BATCH as i64, 3]))
}

impl TaskRunner for RiserStressRunner {
    fn run(&self, ctx: &TaskCtx) -> Result<TaskOutput> {
        let env = broadcast_env(ctx, ["wind", "wave", "depth"])?;
        let out = self.svc.execute("riser_stress", vec![env])?;
        if out.len() != 2 {
            return Err(Error::Runtime(format!(
                "riser_stress returned {} outputs, expected 2",
                out.len()
            )));
        }
        let curv = &out[0]; // (BATCH, 3)
        let (cx, cy, cz) =
            (curv.data[0] as f64, curv.data[1] as f64, curv.data[2] as f64);
        let damage = out[1].data[0] as f64;
        Ok(TaskOutput {
            fields: vec![
                ("cx".into(), cx),
                ("cy".into(), cy),
                ("cz".into(), cz),
                ("damage".into(), damage),
            ],
            files: vec![(
                format!("/data/riser/stress_{:06}.seg", ctx.taskid),
                4096 + (damage.abs() * 1e3) as i64,
            )],
            stdout: format!("cx={cx:.4} cy={cy:.4} cz={cz:.4} damage={damage:.4}"),
        })
    }
}

/// Wear-and-tear runner: inputs `cx`, `cy`, `cz` → output `f1`.
pub struct RiserWearRunner {
    svc: PjrtService,
}

impl RiserWearRunner {
    pub fn new(svc: PjrtService) -> RiserWearRunner {
        RiserWearRunner { svc }
    }
}

impl TaskRunner for RiserWearRunner {
    fn run(&self, ctx: &TaskCtx) -> Result<TaskOutput> {
        let curv = broadcast_env(ctx, ["cx", "cy", "cz"])?;
        let out = self.svc.execute("riser_wear", vec![curv])?;
        let f1 = out
            .first()
            .and_then(|t| t.data.first())
            .copied()
            .ok_or_else(|| Error::Runtime("riser_wear returned no data".into()))?
            as f64;
        Ok(TaskOutput {
            fields: vec![("f1".into(), f1)],
            files: vec![],
            stdout: format!("f1={f1:.5}"),
        })
    }
}

/// Register both riser runners on a registry under the names the
/// `workload::risers_workflow_with(n, Some("riser"))` spec expects.
pub fn register_riser_runners(
    registry: &mut crate::coordinator::payload::RunnerRegistry,
    svc: &PjrtService,
) {
    registry.register("riser", std::sync::Arc::new(RiserStressRunner::new(svc.clone())));
    registry.register("riser_wear", std::sync::Arc::new(RiserWearRunner::new(svc.clone())));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, default_artifact_dir};

    fn ctx(inputs: Vec<(String, f64)>) -> TaskCtx {
        TaskCtx {
            taskid: 7,
            actid: 2,
            workerid: 0,
            inputs,
            seed: 1,
            duration: 0.0,
            time_scale: 0.0,
        }
    }

    #[test]
    fn missing_inputs_are_reported() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let svc = PjrtService::start(default_artifact_dir()).unwrap();
        let r = RiserStressRunner::new(svc);
        let e = r.run(&ctx(vec![("wind".into(), 1.0)]));
        assert!(e.is_err());
    }

    #[test]
    fn stress_then_wear_chain() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let svc = PjrtService::start(default_artifact_dir()).unwrap();
        let stress = RiserStressRunner::new(svc.clone());
        let out = stress
            .run(&ctx(vec![
                ("wind".into(), 12.0),
                ("wave".into(), 0.25),
                ("depth".into(), 1500.0),
            ]))
            .unwrap();
        assert_eq!(out.fields.len(), 4);
        assert_eq!(out.files.len(), 1);

        let wear = RiserWearRunner::new(svc);
        let wout = wear
            .run(&ctx(out.fields[..3].to_vec()))
            .unwrap();
        let f1 = wout.fields[0].1;
        assert!((0.0..=1.0).contains(&f1), "f1={f1}");
    }
}
