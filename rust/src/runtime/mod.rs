//! PJRT runtime: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and executes them from the Rust hot path.
//! Python never runs at request time.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (neither `Send` nor
//! `Sync`), so the runtime runs a dedicated executor thread that owns the
//! client and the compile-once executable cache; worker threads submit
//! requests over a channel. One compiled executable per model variant.

pub mod riser;

use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// A float tensor crossing the service boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Tensor {
        debug_assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>(),
            "tensor data/dims mismatch"
        );
        Tensor { data, dims }
    }
}

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Vec<Tensor>>>,
    },
    Shutdown,
}

/// Handle to the PJRT executor thread. Cheap to clone; thread-safe.
#[derive(Clone)]
pub struct PjrtService {
    tx: Sender<Request>,
    // joined on drop of the last handle
    _joiner: Arc<Joiner>,
}

struct Joiner {
    tx: Sender<Request>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for Joiner {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl PjrtService {
    /// Start the executor thread over an artifact directory containing
    /// `<name>.hlo.txt` files.
    pub fn start(artifact_dir: impl Into<PathBuf>) -> Result<PjrtService> {
        let dir = artifact_dir.into();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ =
                            ready_tx.send(Err(Error::Runtime(format!("PJRT client: {e}"))));
                        return;
                    }
                };
                let mut exes: FxHashMap<String, xla::PjRtLoadedExecutable> =
                    FxHashMap::default();
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Shutdown => break,
                        Request::Execute { artifact, inputs, reply } => {
                            let r = execute_one(&client, &mut exes, &dir, &artifact, inputs);
                            let _ = reply.send(r);
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn pjrt thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt thread died during startup".into()))??;
        Ok(PjrtService {
            tx: tx.clone(),
            _joiner: Arc::new(Joiner { tx, handle: Mutex::new(Some(handle)) }),
        })
    }

    /// Execute `artifact` with the given inputs; blocks for the result.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| Error::Runtime("pjrt executor is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("pjrt executor dropped the request".into()))?
    }
}

/// Executor-thread body for one request: compile-once, run, unpack.
fn execute_one(
    client: &xla::PjRtClient,
    exes: &mut FxHashMap<String, xla::PjRtLoadedExecutable>,
    dir: &Path,
    artifact: &str,
    inputs: Vec<Tensor>,
) -> Result<Vec<Tensor>> {
    if !exes.contains_key(artifact) {
        let path = dir.join(format!("{artifact}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {path:?} not found — run `make artifacts` first"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {artifact}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {artifact}: {e}")))?;
        exes.insert(artifact.to_string(), exe);
    }
    let exe = exes.get(artifact).expect("just inserted");

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            xla::Literal::vec1(&t.data)
                .reshape(&t.dims)
                .map_err(|e| Error::Runtime(format!("input reshape: {e}")))
        })
        .collect::<Result<Vec<_>>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::Runtime(format!("execute {artifact}: {e}")))?;
    let first = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| Error::Runtime("no output buffer".into()))?;
    let lit = first
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("fetch output: {e}")))?;
    // aot.py lowers with return_tuple=True: unpack the tuple elements.
    let elems = lit
        .to_tuple()
        .map_err(|e| Error::Runtime(format!("untuple output: {e}")))?;
    elems
        .into_iter()
        .map(|l| {
            let dims: Vec<i64> = l
                .array_shape()
                .map_err(|e| Error::Runtime(format!("output shape: {e}")))?
                .dims()
                .to_vec();
            let data = l
                .to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("output data: {e}")))?;
            Ok(Tensor { data, dims })
        })
        .collect()
}

/// Default artifact directory: `$SCHALADB_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("SCHALADB_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Whether the riser artifacts exist (tests skip PJRT paths otherwise).
pub fn artifacts_available() -> bool {
    let d = default_artifact_dir();
    d.join("riser_stress.hlo.txt").exists() && d.join("riser_wear.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_invariant() {
        let t = Tensor::new(vec![0.0; 6], vec![2, 3]);
        assert_eq!(t.dims, vec![2, 3]);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let svc = PjrtService::start("/nonexistent-dir").unwrap();
        let e = svc.execute("nope", vec![]);
        match e {
            Err(Error::Runtime(msg)) => assert!(msg.contains("make artifacts"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn service_survives_concurrent_clients() {
        // even without artifacts, concurrent requests must not wedge
        let svc = PjrtService::start("/nonexistent-dir").unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..5 {
                    let _ = svc.execute("nope", vec![]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Full PJRT round trip (needs `make artifacts`; skips otherwise).
    #[test]
    fn riser_stress_artifact_roundtrip() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let svc = PjrtService::start(default_artifact_dir()).unwrap();
        let b = riser::BATCH as i64;
        let env = Tensor::new(
            (0..riser::BATCH)
                .flat_map(|i| [10.0 + i as f32 * 0.1, 0.2, 1000.0])
                .collect(),
            vec![b, 3],
        );
        let out = svc.execute("riser_stress", vec![env.clone()]).unwrap();
        assert_eq!(out.len(), 2, "curv + damage");
        assert_eq!(out[0].dims, vec![b, 3]);
        assert_eq!(out[1].dims, vec![b]);
        assert!(out[0].data.iter().all(|x| x.is_finite()));
        // deterministic across calls
        let out2 = svc.execute("riser_stress", vec![env]).unwrap();
        assert_eq!(out[0], out2[0]);
        assert_eq!(out[1], out2[1]);
    }
}
