//! Always-on cluster observability: a sharded lock-free metrics registry,
//! atomic latency histograms, and per-request span tracing with a bounded
//! slow-op ring.
//!
//! The paper's monitoring chapter stores execution telemetry as regular
//! workflow tables so steering analysts query it through the same OLAP path;
//! this module is the in-process half of that design. Hot paths record into
//! relaxed atomics (claim fast path, 2PL latch waits, scatter scans, WAL
//! group commits, availability sweeps, server frames); the registry is then
//! materialized on demand into the system `monitoring` table by
//! [`crate::storage::DbCluster::refresh_monitoring`] and dumped as
//! Prometheus-style text by [`ObsRegistry::exposition`].
//!
//! Sharding rule: per-partition counters keep [`PART_SHARDS`] shard cells
//! plus a running total, both bumped on every increment (`shard = pidx %
//! PART_SHARDS`), so `total == sum(shards)` whenever writers are quiesced
//! and no cross-shard aggregation is ever needed on the hot path.
//! Per-node cells are exact (one per data node). The whole registry can be
//! quiesced via [`ObsRegistry::set_enabled`]; while disabled the timing
//! helpers return `None` so no `Instant::now()` syscalls are issued at all —
//! that is the "quiesced" arm of the CI overhead gate (`BENCH_obs.json`).

pub mod span;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::metrics::Histogram;
use crate::storage::value::Value;

/// Number of shard cells for per-partition counters. Partitions alias into
/// shards by `pidx % PART_SHARDS`; real deployments in this repo use far
/// fewer partitions than shards, so the mapping is 1:1 in practice.
pub const PART_SHARDS: usize = 64;

/// Capacity of the slow-op ring (top-K slowest spans retained).
pub const SLOW_RING_K: usize = 16;

/// Stage slots tracked per span (see [`Stage`]).
pub const N_STAGES: usize = 4;

/// Per-span stage breakdown slots. `Exec` absorbs the residual time not
/// attributed to any measured stage when the span closes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Stage {
    Latch = 0,
    Exec = 1,
    Wal = 2,
    Scan = 3,
}

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [Stage::Latch, Stage::Exec, Stage::Wal, Stage::Scan];

    pub fn label(self) -> &'static str {
        match self {
            Stage::Latch => "latch",
            Stage::Exec => "exec",
            Stage::Wal => "wal",
            Stage::Scan => "scan",
        }
    }
}

/// Global (cluster-wide) monotonic counters.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Counter {
    /// Prepared DML executions that ran on the compiled fast path
    /// (mirrors `RouteCounters::fast_dml`, including fast point SELECTs).
    DmlFast = 0,
    /// Prepared non-SELECT statements that fell back to the interpreted
    /// 2PL executor (only counted via `exec_prepared`/`exec_prepared_batch`,
    /// so `DmlFast + DmlInterp` reconciles with prepared DML traffic).
    DmlInterp = 1,
    /// SELECTs answered by the scatter-gather engine.
    SelectScatter = 2,
    /// SELECTs answered by the coordinator-side snapshot join.
    SelectSnapshotJoin = 3,
    /// SELECTs that fell back to the centralized 2PL executor.
    SelectCentralized = 4,
    /// Row operations appended to any node WAL.
    WalRecords = 5,
    /// Group-commit flush boundaries hit across all node WALs.
    WalFlushes = 6,
    /// Commits covered by those flushes (mean group size = commits/flushes).
    WalFlushedCommits = 7,
    /// Wire frames read from clients.
    FramesIn = 8,
    /// Wire frames written to clients.
    FramesOut = 9,
    /// Payload+header bytes read from clients.
    BytesIn = 10,
    /// Payload+header bytes written to clients.
    BytesOut = 11,
    /// Malformed/failed frame reads and undecodable requests.
    FrameErrors = 12,
    /// Availability sweeps completed.
    SweepRuns = 13,
    /// Node rejoins completed by the availability sweeper.
    Rejoins = 14,
    /// Times the `monitoring` table was re-materialized.
    MonitoringRefreshes = 15,
    /// Point-DML commits installed by the optimistic (OCC) path's
    /// validation (mirrors `RouteCounters::occ_dml`; no-match OCC reads
    /// and contention fallbacks are not commits and do not count).
    OccDml = 16,
    /// OCC validation conflicts — each one is a retry of the read phase
    /// (mirrors `RouteCounters::occ_retries`).
    OccRetries = 17,
    /// OCC statements that exhausted their retry budget and fell back to
    /// the 2PL fast path (mirrors `RouteCounters::occ_fallbacks`).
    OccFallbacks = 18,
    /// Server connections dropped because a frame read/write exceeded the
    /// configured per-connection timeout (`--conn-timeout-secs`).
    ConnTimeouts = 19,
}

const N_COUNTERS: usize = 20;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::DmlFast,
        Counter::DmlInterp,
        Counter::SelectScatter,
        Counter::SelectSnapshotJoin,
        Counter::SelectCentralized,
        Counter::WalRecords,
        Counter::WalFlushes,
        Counter::WalFlushedCommits,
        Counter::FramesIn,
        Counter::FramesOut,
        Counter::BytesIn,
        Counter::BytesOut,
        Counter::FrameErrors,
        Counter::SweepRuns,
        Counter::Rejoins,
        Counter::MonitoringRefreshes,
        Counter::OccDml,
        Counter::OccRetries,
        Counter::OccFallbacks,
        Counter::ConnTimeouts,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Counter::DmlFast => "dml_fast",
            Counter::DmlInterp => "dml_interp",
            Counter::SelectScatter => "select_scatter",
            Counter::SelectSnapshotJoin => "select_snapshot_join",
            Counter::SelectCentralized => "select_centralized",
            Counter::WalRecords => "wal_records",
            Counter::WalFlushes => "wal_flushes",
            Counter::WalFlushedCommits => "wal_flushed_commits",
            Counter::FramesIn => "server_frames_in",
            Counter::FramesOut => "server_frames_out",
            Counter::BytesIn => "server_bytes_in",
            Counter::BytesOut => "server_bytes_out",
            Counter::FrameErrors => "server_frame_errors",
            Counter::SweepRuns => "sweep_runs",
            Counter::Rejoins => "rejoins",
            Counter::MonitoringRefreshes => "monitoring_refreshes",
            Counter::OccDml => "occ_dml",
            Counter::OccRetries => "occ_retries",
            Counter::OccFallbacks => "occ_fallbacks",
            Counter::ConnTimeouts => "server_conn_timeouts",
        }
    }
}

/// Latency histograms kept by the registry, one [`AtomicHistogram`] each.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Hist {
    /// Compiled-fast-path prepared DML latency (claim loop hot path).
    ClaimFast = 0,
    /// Interpreted-fallback prepared DML latency.
    ClaimInterp = 1,
    /// 2PL latch acquisition wait (growing phase, fast + interpreted paths).
    LatchWait = 2,
    /// Scatter-gather / snapshot-join scan latency.
    ScatterScan = 3,
    /// WAL commit-call latency when a group-commit flush boundary was hit.
    WalFlush = 4,
    /// Availability sweep duration.
    Sweep = 5,
    /// Per-node rejoin duration (catch-up rounds + final cut).
    Rejoin = 6,
    /// OCC commit-critical-section latency (latch + stamp revalidation +
    /// install), one sample per validation attempt. Structurally,
    /// `count == OccDml + OccRetries`: every attempt either commits or
    /// conflicts (`tests/obs_telemetry.rs` asserts this).
    OccValidate = 7,
    /// Retries-per-statement distribution for OCC statements that entered
    /// the commit section, recorded at statement completion (commit or
    /// fallback) through the same log2 buckets as the latency histograms
    /// with 1 retry ≡ 1 µs — so bucket 0 is "committed first try".
    /// Structurally, `count == OccDml + OccFallbacks`.
    OccRetryDist = 8,
}

const N_HISTS: usize = 9;

impl Hist {
    pub const ALL: [Hist; N_HISTS] = [
        Hist::ClaimFast,
        Hist::ClaimInterp,
        Hist::LatchWait,
        Hist::ScatterScan,
        Hist::WalFlush,
        Hist::Sweep,
        Hist::Rejoin,
        Hist::OccValidate,
        Hist::OccRetryDist,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Hist::ClaimFast => "claim_fast",
            Hist::ClaimInterp => "claim_interp",
            Hist::LatchWait => "latch_wait",
            Hist::ScatterScan => "scatter_scan",
            Hist::WalFlush => "wal_flush",
            Hist::Sweep => "sweep",
            Hist::Rejoin => "rejoin",
            Hist::OccValidate => "occ_validate",
            Hist::OccRetryDist => "occ_retry_dist",
        }
    }
}

/// Per-partition counters (sharded; see module docs for the sharding rule).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PartMetric {
    /// DML claims executed against the partition (compiled fast path).
    Claims = 0,
    /// Scatter/snapshot scans that touched the partition.
    Scans = 1,
    /// WAL row operations appended for the partition.
    WalRecords = 2,
}

const N_PART_METRICS: usize = 3;

impl PartMetric {
    pub const ALL: [PartMetric; N_PART_METRICS] =
        [PartMetric::Claims, PartMetric::Scans, PartMetric::WalRecords];

    pub fn label(self) -> &'static str {
        match self {
            PartMetric::Claims => "part_claims",
            PartMetric::Scans => "part_scans",
            PartMetric::WalRecords => "part_wal_records",
        }
    }
}

/// Lock-free fixed-bucket latency histogram. Bucket layout is identical to
/// [`Histogram`] (log2 µs buckets, bucket 0 = sub-µs), so [`snapshot`]
/// round-trips losslessly through [`Histogram::from_parts`] and snapshots
/// from different shards/nodes merge with [`Histogram::merge`].
///
/// [`snapshot`]: AtomicHistogram::snapshot
pub struct AtomicHistogram {
    buckets: Vec<AtomicU64>,
    sum_nanos: AtomicU64,
    min_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl AtomicHistogram {
    pub fn new() -> AtomicHistogram {
        AtomicHistogram {
            buckets: (0..Histogram::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_nanos: AtomicU64::new(0),
            min_nanos: AtomicU64::new(u64::MAX),
            max_nanos: AtomicU64::new(0),
        }
    }

    /// Integer twin of `Histogram::bucket_of`: for whole-µs values the two
    /// agree exactly because `floor(log2(floor(x))) == floor(log2(x))` for
    /// `x >= 1` (a power of two can never sit strictly between `floor(x)`
    /// and `x`).
    fn bucket_of_nanos(nanos: u64) -> usize {
        let us = nanos / 1_000;
        if us == 0 {
            return 0;
        }
        ((63 - us.leading_zeros()) as usize + 1).min(Histogram::BUCKETS - 1)
    }

    pub fn record_nanos(&self, nanos: u64) {
        self.buckets[Self::bucket_of_nanos(nanos)].fetch_add(1, Relaxed);
        self.sum_nanos.fetch_add(nanos, Relaxed);
        self.min_nanos.fetch_min(nanos, Relaxed);
        self.max_nanos.fetch_max(nanos, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Relaxed)).sum()
    }

    /// Zero all state (quiesce→resume restart of the observation window).
    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Relaxed);
        }
        self.sum_nanos.store(0, Relaxed);
        self.min_nanos.store(u64::MAX, Relaxed);
        self.max_nanos.store(0, Relaxed);
    }

    /// Materialize a point-in-time [`Histogram`] (exact when writers are
    /// quiesced, approximate under concurrent recording).
    pub fn snapshot(&self) -> Histogram {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        let sum = self.sum_nanos.load(Relaxed) as f64 * 1e-9;
        let min_n = self.min_nanos.load(Relaxed);
        let min = if min_n == u64::MAX { f64::INFINITY } else { min_n as f64 * 1e-9 };
        let max = self.max_nanos.load(Relaxed) as f64 * 1e-9;
        Histogram::from_parts(buckets, sum, min, max)
    }
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A per-partition counter: shard cells plus a running total, both bumped on
/// every increment so the total needs no cross-shard fold on read.
struct Sharded {
    shards: Vec<AtomicU64>,
    total: AtomicU64,
}

impl Sharded {
    fn new() -> Sharded {
        Sharded {
            shards: (0..PART_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
        }
    }

    fn add(&self, pidx: usize, n: u64) {
        self.shards[pidx % PART_SHARDS].fetch_add(n, Relaxed);
        self.total.fetch_add(n, Relaxed);
    }

    fn reset(&self) {
        for s in &self.shards {
            s.store(0, Relaxed);
        }
        self.total.store(0, Relaxed);
    }
}

/// Cells per lazily-allocated node-ledger block.
const NODE_BLOCK: usize = 64;
/// Spine capacity: `NODE_BLOCKS * NODE_BLOCK` addressable nodes.
const NODE_BLOCKS: usize = 64;

/// Growable per-node counter ledger: a fixed spine of lazily-allocated
/// [`NODE_BLOCK`]-cell blocks. `ensure` extends coverage after `add_node`
/// without ever moving existing cells, so the hot `add`/`get` path stays
/// lock-free (block pointers are `OnceLock`-published, length is a relaxed
/// high-water mark). Nodes past the spine capacity (4096) are ignored, the
/// same contract the old fixed vector had for out-of-range ids.
struct NodeLedger {
    blocks: Vec<OnceLock<Box<[AtomicU64]>>>,
    len: AtomicUsize,
}

impl NodeLedger {
    fn new(len: usize) -> NodeLedger {
        let l = NodeLedger {
            blocks: (0..NODE_BLOCKS).map(|_| OnceLock::new()).collect(),
            len: AtomicUsize::new(0),
        };
        l.ensure(len);
        l
    }

    /// Grow coverage to at least `len` cells (never shrinks).
    fn ensure(&self, len: usize) {
        let len = len.min(NODE_BLOCK * NODE_BLOCKS);
        let blocks_needed = (len + NODE_BLOCK - 1) / NODE_BLOCK;
        for b in 0..blocks_needed {
            self.blocks[b].get_or_init(|| (0..NODE_BLOCK).map(|_| AtomicU64::new(0)).collect());
        }
        self.len.fetch_max(len, Relaxed);
    }

    fn len(&self) -> usize {
        self.len.load(Relaxed)
    }

    fn cell(&self, i: usize) -> Option<&AtomicU64> {
        if i >= self.len() {
            return None;
        }
        self.blocks.get(i / NODE_BLOCK)?.get().map(|b| &b[i % NODE_BLOCK])
    }

    fn add(&self, i: usize, n: u64) {
        if let Some(c) = self.cell(i) {
            c.fetch_add(n, Relaxed);
        }
    }

    fn get(&self, i: usize) -> u64 {
        self.cell(i).map_or(0, |c| c.load(Relaxed))
    }

    fn reset(&self) {
        for b in self.blocks.iter().filter_map(|b| b.get()) {
            for c in b.iter() {
                c.store(0, Relaxed);
            }
        }
    }
}

/// One completed span retained by the slow-op ring.
#[derive(Clone, Debug)]
pub struct SlowOp {
    pub span: u64,
    pub label: &'static str,
    pub total_nanos: u64,
    /// Nanoseconds per [`Stage`], indexed by `Stage as usize`.
    pub stages: [u64; N_STAGES],
}

/// Bounded top-K slowest-spans buffer. An atomic floor lets the hot path
/// skip the mutex for ops that cannot possibly rank.
struct SlowRing {
    floor_nanos: AtomicU64,
    ops: Mutex<Vec<SlowOp>>,
}

impl SlowRing {
    fn new() -> SlowRing {
        SlowRing { floor_nanos: AtomicU64::new(0), ops: Mutex::new(Vec::new()) }
    }

    fn note(&self, op: SlowOp) {
        if op.total_nanos <= self.floor_nanos.load(Relaxed) {
            return;
        }
        let mut ops = self.ops.lock().unwrap();
        ops.push(op);
        ops.sort_by(|a, b| b.total_nanos.cmp(&a.total_nanos));
        ops.truncate(SLOW_RING_K);
        if ops.len() == SLOW_RING_K {
            self.floor_nanos.store(ops[SLOW_RING_K - 1].total_nanos, Relaxed);
        }
    }

    fn top(&self, k: usize) -> Vec<SlowOp> {
        let ops = self.ops.lock().unwrap();
        ops.iter().take(k).cloned().collect()
    }
}

/// The cluster-wide metrics registry. One instance lives on `DbCluster`
/// (shared with every `DataNode` and the wire server) for the lifetime of
/// the cluster; all mutation is relaxed-atomic.
pub struct ObsRegistry {
    enabled: AtomicBool,
    counters: Vec<AtomicU64>,
    hists: Vec<AtomicHistogram>,
    parts: Vec<Sharded>,
    node_wal_records: NodeLedger,
    node_wal_flushes: NodeLedger,
    slow: SlowRing,
    next_span: AtomicU64,
}

impl ObsRegistry {
    pub fn new(num_nodes: usize) -> ObsRegistry {
        ObsRegistry {
            enabled: AtomicBool::new(true),
            counters: (0..N_COUNTERS).map(|_| AtomicU64::new(0)).collect(),
            hists: (0..N_HISTS).map(|_| AtomicHistogram::new()).collect(),
            parts: (0..N_PART_METRICS).map(|_| Sharded::new()).collect(),
            node_wal_records: NodeLedger::new(num_nodes),
            node_wal_flushes: NodeLedger::new(num_nodes),
            slow: SlowRing::new(),
            next_span: AtomicU64::new(1),
        }
    }

    /// Quiesce (`false`) or re-enable (`true`) all instrumentation. While
    /// quiesced, counters stop moving and the timing helpers skip their
    /// `Instant::now()` calls entirely.
    ///
    /// Resuming from a quiesce **resets** every counter, histogram,
    /// per-partition shard, and per-node WAL ledger: a quiesce window is a
    /// hole in the observation stream, and restarting from zero keeps the
    /// registry internally consistent (`count == sum of its histogram's
    /// buckets`, counters == their paired histogram counts) instead of
    /// resuming mid-stream with invariant-breaking gaps. Readers that
    /// difference successive snapshots (`dchiron top`) must therefore
    /// clamp negative deltas to zero — see `cmd_top`.
    pub fn set_enabled(&self, on: bool) {
        let was = self.enabled.swap(on, Relaxed);
        if on && !was {
            for c in &self.counters {
                c.store(0, Relaxed);
            }
            for h in &self.hists {
                h.reset();
            }
            for p in &self.parts {
                p.reset();
            }
            self.node_wal_records.reset();
            self.node_wal_flushes.reset();
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Relaxed)
    }

    pub fn inc(&self, c: Counter) {
        self.addc(c, 1);
    }

    pub fn addc(&self, c: Counter, n: u64) {
        if self.is_enabled() {
            self.counters[c as usize].fetch_add(n, Relaxed);
        }
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize].load(Relaxed)
    }

    pub fn part_add(&self, m: PartMetric, pidx: usize, n: u64) {
        if self.is_enabled() {
            self.parts[m as usize].add(pidx, n);
        }
    }

    pub fn part_add_list(&self, m: PartMetric, parts: &[usize]) {
        if self.is_enabled() {
            for &p in parts {
                self.parts[m as usize].add(p, 1);
            }
        }
    }

    pub fn part_total(&self, m: PartMetric) -> u64 {
        self.parts[m as usize].total.load(Relaxed)
    }

    pub fn part_shard(&self, m: PartMetric, shard: usize) -> u64 {
        self.parts[m as usize].shards[shard % PART_SHARDS].load(Relaxed)
    }

    /// Extend the per-node WAL ledgers to cover node `id`. Called by
    /// `add_node`, so nodes added after construction get `node_wal_*`
    /// breakouts instead of being silently dropped.
    pub fn ensure_node(&self, id: usize) {
        self.node_wal_records.ensure(id + 1);
        self.node_wal_flushes.ensure(id + 1);
    }

    pub fn node_wal(&self, node: usize, records: u64, flushed: bool) {
        if !self.is_enabled() {
            return;
        }
        self.node_wal_records.add(node, records);
        if flushed {
            self.node_wal_flushes.add(node, 1);
        }
    }

    pub fn node_wal_records(&self, node: usize) -> u64 {
        self.node_wal_records.get(node)
    }

    pub fn node_wal_flushes(&self, node: usize) -> u64 {
        self.node_wal_flushes.get(node)
    }

    pub fn num_nodes(&self) -> usize {
        self.node_wal_records.len()
    }

    /// Start a latency measurement; `None` while quiesced (no clock read).
    pub fn start(&self) -> Option<Instant> {
        if self.is_enabled() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Record the time elapsed since [`start`](ObsRegistry::start) into
    /// histogram `h`; returns the elapsed nanos for span-stage attribution.
    pub fn rec_since(&self, h: Hist, t0: Option<Instant>) -> Option<u64> {
        let t0 = t0?;
        let nanos = t0.elapsed().as_nanos() as u64;
        self.rec_nanos(h, nanos);
        Some(nanos)
    }

    pub fn rec_nanos(&self, h: Hist, nanos: u64) {
        if self.is_enabled() {
            self.hists[h as usize].record_nanos(nanos);
        }
    }

    /// Record a unitless count `n` into histogram `h` under the
    /// 1 count ≡ 1 µs convention (see [`Hist::OccRetryDist`]): bucket 0
    /// holds the zeros and bucket `k` holds counts in `[2^(k-1), 2^k)`.
    pub fn rec_count(&self, h: Hist, n: u64) {
        self.rec_nanos(h, n.saturating_mul(1_000));
    }

    pub fn hist(&self, h: Hist) -> Histogram {
        self.hists[h as usize].snapshot()
    }

    pub fn mint_span(&self) -> u64 {
        self.next_span.fetch_add(1, Relaxed)
    }

    pub(crate) fn note_slow(&self, op: SlowOp) {
        self.slow.note(op);
    }

    /// Top-`k` slowest completed spans, slowest first.
    pub fn slow_ops(&self, k: usize) -> Vec<SlowOp> {
        self.slow.top(k.min(SLOW_RING_K))
    }

    /// Prometheus-style text exposition of every counter, per-partition and
    /// per-node cell, and histogram summary.
    pub fn exposition(&self) -> String {
        let mut s = String::new();
        for c in Counter::ALL {
            let name = format!("schaladb_{}_total", c.label());
            s.push_str(&format!("# TYPE {name} counter\n"));
            s.push_str(&format!("{name} {}\n", self.counter(c)));
        }
        for m in PartMetric::ALL {
            let name = format!("schaladb_{}_total", m.label());
            s.push_str(&format!("# TYPE {name} counter\n"));
            s.push_str(&format!("{name} {}\n", self.part_total(m)));
            for shard in 0..PART_SHARDS {
                let v = self.part_shard(m, shard);
                if v != 0 {
                    s.push_str(&format!("{name}{{part=\"{shard}\"}} {v}\n"));
                }
            }
        }
        for node in 0..self.num_nodes() {
            s.push_str(&format!(
                "schaladb_node_wal_records_total{{node=\"{node}\"}} {}\n",
                self.node_wal_records(node)
            ));
            s.push_str(&format!(
                "schaladb_node_wal_flushes_total{{node=\"{node}\"}} {}\n",
                self.node_wal_flushes(node)
            ));
        }
        for h in Hist::ALL {
            let snap = self.hist(h);
            let name = format!("schaladb_{}_seconds", h.label());
            s.push_str(&format!("# TYPE {name} summary\n"));
            s.push_str(&format!("{name}{{quantile=\"0.5\"}} {:.9}\n", snap.quantile(0.5)));
            s.push_str(&format!("{name}{{quantile=\"0.99\"}} {:.9}\n", snap.quantile(0.99)));
            s.push_str(&format!("{name}_sum {:.9}\n", snap.mean() * snap.count() as f64));
            s.push_str(&format!("{name}_count {}\n", snap.count()));
        }
        s
    }

    /// Rows for the system `monitoring` table, in column order
    /// `(mid, metric, part, node, epoch, value, count)`. Global rows carry
    /// `part = -1, node = -1`; per-partition rows carry the shard index in
    /// `part`; per-node rows carry the node id in `node`. Exact when
    /// writers are quiesced; internally consistent (each sharded metric's
    /// global row equals the sum of its part rows) under the same condition.
    pub fn monitoring_rows(&self, epoch: u64) -> Vec<Vec<Value>> {
        let mut rows: Vec<Vec<Value>> = Vec::new();
        let mut mid: i64 = 0;
        let mut push = |metric: String, part: i64, node: i64, value: f64, count: u64| {
            rows.push(vec![
                Value::Int(mid),
                Value::str(&metric),
                Value::Int(part),
                Value::Int(node),
                Value::Int(epoch as i64),
                Value::Float(value),
                Value::Int(count as i64),
            ]);
            mid += 1;
        };
        for c in Counter::ALL {
            let v = self.counter(c);
            push(c.label().to_string(), -1, -1, v as f64, v);
        }
        for m in PartMetric::ALL {
            let total = self.part_total(m);
            push(m.label().to_string(), -1, -1, total as f64, total);
            for shard in 0..PART_SHARDS {
                let v = self.part_shard(m, shard);
                if v != 0 {
                    push(m.label().to_string(), shard as i64, -1, v as f64, v);
                }
            }
        }
        for node in 0..self.num_nodes() {
            let r = self.node_wal_records(node);
            push("node_wal_records".to_string(), -1, node as i64, r as f64, r);
            let f = self.node_wal_flushes(node);
            push("node_wal_flushes".to_string(), -1, node as i64, f as f64, f);
        }
        for h in Hist::ALL {
            let snap = self.hist(h);
            let n = snap.count();
            push(format!("{}_p50_seconds", h.label()), -1, -1, snap.quantile(0.5), n);
            push(format!("{}_p99_seconds", h.label()), -1, -1, snap.quantile(0.99), n);
            push(format!("{}_mean_seconds", h.label()), -1, -1, snap.mean(), n);
            push(format!("{}_max_seconds", h.label()), -1, -1, snap.max(), n);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_histogram_matches_scalar_bucketing() {
        let ah = AtomicHistogram::new();
        let mut h = Histogram::new();
        // spread across sub-µs, µs, ms, and multi-second buckets
        for nanos in [1u64, 500, 999, 1_000, 1_500, 2_000, 65_000, 3_000_000, 2_500_000_000] {
            ah.record_nanos(nanos);
            h.record(nanos as f64 * 1e-9);
        }
        let snap = ah.snapshot();
        assert_eq!(snap.count(), h.count());
        for q in [0.0, 0.5, 0.99, 1.0] {
            let (a, b) = (snap.quantile(q), h.quantile(q));
            assert!((a - b).abs() < 1e-9, "q{q}: atomic {a} vs scalar {b}");
        }
        assert!((snap.mean() - h.mean()).abs() < 1e-12);
        assert!((snap.min() - h.min()).abs() < 1e-12);
        assert!((snap.max() - h.max()).abs() < 1e-12);
    }

    #[test]
    fn sharded_total_equals_sum_of_shards() {
        let reg = ObsRegistry::new(2);
        for p in 0..10 {
            reg.part_add(PartMetric::Claims, p, (p + 1) as u64);
        }
        reg.part_add_list(PartMetric::Claims, &[0, 1, 0]);
        let sum: u64 = (0..PART_SHARDS).map(|s| reg.part_shard(PartMetric::Claims, s)).sum();
        assert_eq!(reg.part_total(PartMetric::Claims), sum);
        assert_eq!(sum, 55 + 3);
    }

    #[test]
    fn node_ledger_grows_past_initial_sizing() {
        let reg = ObsRegistry::new(2);
        assert_eq!(reg.num_nodes(), 2);
        reg.node_wal(2, 7, true); // out of range: silently dropped
        assert_eq!(reg.node_wal_records(2), 0);
        reg.ensure_node(2);
        assert_eq!(reg.num_nodes(), 3);
        reg.node_wal(2, 7, true);
        assert_eq!(reg.node_wal_records(2), 7);
        assert_eq!(reg.node_wal_flushes(2), 1);
        // spill into a second lazily-allocated block
        reg.ensure_node(100);
        reg.node_wal(100, 1, false);
        assert_eq!(reg.num_nodes(), 101);
        assert_eq!(reg.node_wal_records(100), 1);
        // quiesce→resume resets grown cells too
        reg.set_enabled(false);
        reg.set_enabled(true);
        assert_eq!(reg.node_wal_records(2), 0);
        assert_eq!(reg.node_wal_records(100), 0);
    }

    #[test]
    fn quiesced_registry_records_nothing() {
        let reg = ObsRegistry::new(1);
        reg.set_enabled(false);
        assert!(reg.start().is_none());
        reg.inc(Counter::DmlFast);
        reg.part_add(PartMetric::Scans, 0, 5);
        reg.rec_nanos(Hist::ClaimFast, 1_000);
        reg.node_wal(0, 3, true);
        assert_eq!(reg.counter(Counter::DmlFast), 0);
        assert_eq!(reg.part_total(PartMetric::Scans), 0);
        assert_eq!(reg.hist(Hist::ClaimFast).count(), 0);
        assert_eq!(reg.node_wal_records(0), 0);
        reg.set_enabled(true);
        reg.inc(Counter::DmlFast);
        assert_eq!(reg.counter(Counter::DmlFast), 1);
    }

    #[test]
    fn resume_from_quiesce_restarts_the_observation_window_at_zero() {
        let reg = ObsRegistry::new(2);
        reg.inc(Counter::DmlFast);
        reg.addc(Counter::WalRecords, 7);
        reg.rec_nanos(Hist::ClaimFast, 5_000);
        reg.part_add(PartMetric::Claims, 1, 3);
        reg.node_wal(1, 4, true);
        reg.set_enabled(false);
        reg.set_enabled(true); // resume: everything restarts from zero
        assert_eq!(reg.counter(Counter::DmlFast), 0);
        assert_eq!(reg.counter(Counter::WalRecords), 0);
        assert_eq!(reg.hist(Hist::ClaimFast).count(), 0);
        assert_eq!(reg.part_total(PartMetric::Claims), 0);
        assert_eq!(reg.part_shard(PartMetric::Claims, 1), 0);
        assert_eq!(reg.node_wal_records(1), 0);
        assert_eq!(reg.node_wal_flushes(1), 0);
        // and the window records normally afterwards
        reg.inc(Counter::DmlFast);
        reg.rec_nanos(Hist::ClaimFast, 2_000);
        assert_eq!(reg.counter(Counter::DmlFast), 1);
        assert_eq!(reg.hist(Hist::ClaimFast).count(), 1);
        // enabling an already-enabled registry is a no-op, not a reset
        reg.set_enabled(true);
        assert_eq!(reg.counter(Counter::DmlFast), 1);
    }

    #[test]
    fn rec_count_buckets_zero_separately_from_small_counts() {
        let reg = ObsRegistry::new(1);
        reg.rec_count(Hist::OccRetryDist, 0);
        reg.rec_count(Hist::OccRetryDist, 1);
        reg.rec_count(Hist::OccRetryDist, 3);
        let h = reg.hist(Hist::OccRetryDist);
        assert_eq!(h.count(), 3);
        // mean in "seconds" is count * 1e-6: (0 + 1 + 3) / 3 µs
        assert!((h.mean() - (4.0 / 3.0) * 1e-6).abs() < 1e-12);
    }

    #[test]
    fn slow_ring_keeps_top_k() {
        let ring = SlowRing::new();
        for i in 0..100u64 {
            ring.note(SlowOp { span: i, label: "op", total_nanos: i * 10, stages: [0; N_STAGES] });
        }
        let top = ring.top(SLOW_RING_K);
        assert_eq!(top.len(), SLOW_RING_K);
        assert_eq!(top[0].total_nanos, 990);
        assert!(top.windows(2).all(|w| w[0].total_nanos >= w[1].total_nanos));
        // floor prunes ops that cannot rank
        ring.note(SlowOp { span: 200, label: "op", total_nanos: 1, stages: [0; N_STAGES] });
        assert_eq!(ring.top(SLOW_RING_K)[SLOW_RING_K - 1].total_nanos, 990 - 10 * 15);
    }

    #[test]
    fn exposition_lines_parse() {
        let reg = ObsRegistry::new(2);
        reg.inc(Counter::DmlFast);
        reg.part_add(PartMetric::Claims, 3, 7);
        reg.rec_nanos(Hist::ClaimFast, 12_345);
        reg.node_wal(1, 4, true);
        let text = reg.exposition();
        assert!(text.contains("schaladb_dml_fast_total 1"));
        assert!(text.contains("schaladb_part_claims_total{part=\"3\"} 7"));
        assert!(text.contains("schaladb_node_wal_records_total{node=\"1\"} 4"));
        assert!(text.contains("schaladb_claim_fast_seconds_count 1"));
        for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
    }

    #[test]
    fn monitoring_rows_are_internally_consistent() {
        let reg = ObsRegistry::new(2);
        for p in 0..4 {
            reg.part_add(PartMetric::Claims, p, 10 * (p as u64 + 1));
        }
        reg.inc(Counter::SelectScatter);
        let rows = reg.monitoring_rows(7);
        // mids are unique and sequential
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r[0], Value::Int(i as i64));
            assert_eq!(r[4], Value::Int(7));
        }
        let claims: Vec<&Vec<Value>> = rows
            .iter()
            .filter(|r| r[1] == Value::str(PartMetric::Claims.label()))
            .collect();
        let global: i64 = claims
            .iter()
            .filter(|r| r[2] == Value::Int(-1))
            .map(|r| r[6].as_i64().expect("count is int"))
            .sum();
        let parts: i64 = claims
            .iter()
            .filter(|r| r[2] != Value::Int(-1))
            .map(|r| r[6].as_i64().expect("count is int"))
            .sum();
        assert_eq!(global, 100);
        assert_eq!(parts, 100);
    }
}
