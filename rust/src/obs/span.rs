//! Per-request span tracing.
//!
//! A span id is minted at the outermost entry point of a request (session
//! method, connector call, or direct `DbCluster` API) and lives in
//! thread-local state while the request executes — valid because every
//! execution path in this engine is synchronous on the calling thread (the
//! scan pool runs leaf closures, but all instrumented stages are recorded by
//! the coordinator thread). Inner layers attribute measured time to stages
//! via [`stage_add`]; nested `begin` calls on the same thread are no-ops, so
//! the outermost caller owns the span. When the guard drops, unattributed
//! time is folded into [`Stage::Exec`] and the completed span competes for a
//! slot in the registry's bounded slow-op ring.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use super::{ObsRegistry, SlowOp, Stage, N_STAGES};

struct SpanState {
    span: u64,
    stages: [u64; N_STAGES],
}

thread_local! {
    static ACTIVE: RefCell<Option<SpanState>> = const { RefCell::new(None) };
}

/// RAII guard for an in-flight span. Inert (all fields `None`) when the
/// registry is quiesced or an outer span already owns this thread.
pub struct SpanGuard {
    reg: Option<Arc<ObsRegistry>>,
    label: &'static str,
    t0: Option<Instant>,
}

/// Open a span if the registry is enabled and no span is active on this
/// thread; otherwise return an inert guard.
pub fn begin(reg: &Arc<ObsRegistry>, label: &'static str) -> SpanGuard {
    if !reg.is_enabled() {
        return SpanGuard { reg: None, label, t0: None };
    }
    let opened = ACTIVE.with(|a| {
        let mut a = a.borrow_mut();
        if a.is_some() {
            false
        } else {
            *a = Some(SpanState { span: reg.mint_span(), stages: [0; N_STAGES] });
            true
        }
    });
    if !opened {
        return SpanGuard { reg: None, label, t0: None };
    }
    SpanGuard { reg: Some(reg.clone()), label, t0: Some(Instant::now()) }
}

/// Attribute `nanos` to `stage` of the span active on this thread (no-op
/// when none is).
pub fn stage_add(stage: Stage, nanos: u64) {
    ACTIVE.with(|a| {
        if let Some(s) = a.borrow_mut().as_mut() {
            s.stages[stage as usize] += nanos;
        }
    });
}

/// Span id active on this thread, if any (for log/debug correlation).
pub fn current_span() -> Option<u64> {
    ACTIVE.with(|a| a.borrow().as_ref().map(|s| s.span))
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(reg) = self.reg.take() else { return };
        let total = self.t0.map_or(0, |t| t.elapsed().as_nanos() as u64);
        let Some(mut st) = ACTIVE.with(|a| a.borrow_mut().take()) else { return };
        let accounted: u64 = st.stages.iter().sum();
        st.stages[Stage::Exec as usize] += total.saturating_sub(accounted);
        reg.note_slow(SlowOp {
            span: st.span,
            label: self.label,
            total_nanos: total,
            stages: st.stages,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outermost_span_owns_thread_and_records_stages() {
        let reg = Arc::new(ObsRegistry::new(1));
        {
            let _outer = begin(&reg, "outer");
            assert!(current_span().is_some());
            {
                let _inner = begin(&reg, "inner"); // inert: outer owns thread
                stage_add(Stage::Latch, 1_000);
            }
            // inner guard dropping must not close the outer span
            assert!(current_span().is_some());
            stage_add(Stage::Wal, 2_000);
        }
        assert!(current_span().is_none());
        let ops = reg.slow_ops(super::super::SLOW_RING_K);
        assert_eq!(ops.len(), 1);
        let op = &ops[0];
        assert_eq!(op.label, "outer");
        assert_eq!(op.stages[Stage::Latch as usize], 1_000);
        assert_eq!(op.stages[Stage::Wal as usize], 2_000);
        // residual went to Exec; stage sum equals the total
        assert_eq!(op.stages.iter().sum::<u64>(), op.total_nanos.max(3_000));
    }

    #[test]
    fn quiesced_registry_opens_no_span() {
        let reg = Arc::new(ObsRegistry::new(1));
        reg.set_enabled(false);
        {
            let _g = begin(&reg, "noop");
            assert!(current_span().is_none());
            stage_add(Stage::Scan, 5_000);
        }
        assert!(reg.slow_ops(4).is_empty());
    }
}
