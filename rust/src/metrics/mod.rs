//! Reporting helpers: run-report rendering, latency histograms, and the
//! machine-readable JSON emitted next to every bench table.

use crate::coordinator::engine::RunReport;
use crate::util::json::Json;
use crate::util::{fmt_secs, render_table};

/// Render a [`RunReport`] as the text block printed by examples and benches.
pub fn format_report(title: &str, r: &RunReport) -> String {
    let mut s = String::new();
    s.push_str(&format!("== {title} ==\n"));
    s.push_str(&format!(
        "makespan {}  tasks {}/{} executed  failures {}  claim-races {}\n",
        fmt_secs(r.makespan_secs),
        r.executed_tasks,
        r.total_tasks,
        r.failed_tasks,
        r.claim_races_lost
    ));
    s.push_str(&format!(
        "DBMS: total {}  max-node {}  share-of-makespan {:.1}%  db {} KB  sup-failovers {}\n",
        fmt_secs(r.dbms_total_secs),
        fmt_secs(r.dbms_max_node_secs),
        100.0 * r.dbms_max_node_secs / r.makespan_secs.max(1e-12),
        r.db_bytes / 1024,
        r.supervisor_failovers
    ));
    let rows: Vec<Vec<String>> = r
        .access_stats
        .iter()
        .map(|(k, st)| {
            vec![
                k.label().to_string(),
                st.count.to_string(),
                fmt_secs(st.total_secs),
                fmt_secs(st.mean_secs()),
                format!("{:.1}%", 100.0 * st.total_secs / r.dbms_total_secs.max(1e-12)),
            ]
        })
        .collect();
    s.push_str(&render_table(&["access", "count", "total", "mean", "share"], &rows));
    s
}

/// JSON form of a run report (for plotting scripts).
pub fn report_json(label: &str, r: &RunReport) -> Json {
    let mut accesses = Json::obj();
    for (k, st) in &r.access_stats {
        accesses = accesses.set(
            k.label(),
            Json::obj()
                .set("count", st.count as i64)
                .set("total_secs", st.total_secs)
                .set("mean_secs", st.mean_secs()),
        );
    }
    Json::obj()
        .set("label", label)
        .set("makespan_secs", r.makespan_secs)
        .set("total_tasks", r.total_tasks)
        .set("executed_tasks", r.executed_tasks as i64)
        .set("dbms_total_secs", r.dbms_total_secs)
        .set("dbms_max_node_secs", r.dbms_max_node_secs)
        .set("db_bytes", r.db_bytes)
        .set("accesses", accesses)
}

/// Fixed-bucket latency histogram (log2 buckets from 1 µs to ~1 min).
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Bucket count shared with [`crate::obs::AtomicHistogram`], which must
    /// place samples identically so `snapshot()` round-trips through
    /// [`Histogram::from_parts`].
    pub const BUCKETS: usize = 28;

    pub fn new() -> Histogram {
        Histogram {
            buckets: vec![0; Self::BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Rebuild a histogram from raw parts (bucket counts plus the running
    /// sum/min/max), e.g. from an atomic registry snapshot. `min`/`max` are
    /// ignored when the buckets are empty.
    pub fn from_parts(buckets: Vec<u64>, sum: f64, min: f64, max: f64) -> Histogram {
        assert_eq!(buckets.len(), Self::BUCKETS, "bucket layout mismatch");
        let count: u64 = buckets.iter().sum();
        Histogram {
            buckets,
            count,
            sum,
            min: if count == 0 { f64::INFINITY } else { min },
            max: if count == 0 { 0.0 } else { max },
        }
    }

    pub(crate) fn bucket_of(secs: f64) -> usize {
        // bucket 0: < 1us; each bucket doubles
        let us = secs * 1e6;
        if us < 1.0 {
            return 0;
        }
        (us.log2().floor() as usize + 1).min(Self::BUCKETS - 1)
    }

    pub fn record(&mut self, secs: f64) {
        self.buckets[Self::bucket_of(secs)] += 1;
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest recorded sample, or 0 for an empty histogram.
    pub fn min(&self) -> f64 {
        if self.min.is_finite() {
            self.min
        } else {
            0.0
        }
    }

    /// Largest recorded sample, or 0 for an empty histogram.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold `other` into `self`; the result is indistinguishable from having
    /// recorded both sample streams into a single histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, ob) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += ob;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket),
    /// clamped into the observed `[min, max]` range so bucket 0 reports the
    /// true smallest sample rather than a fixed 1 µs edge.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                // upper edge of bucket i in seconds
                let edge = if i == 0 { 1e-6 } else { (1u64 << (i - 1)) as f64 * 1e-6 * 2.0 };
                return edge.clamp(self.min, self.max.max(self.min));
            }
        }
        self.max
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} min={} max={}",
            self.count,
            fmt_secs(self.mean()),
            fmt_secs(self.quantile(0.5)),
            fmt_secs(self.quantile(0.99)),
            fmt_secs(self.min()),
            fmt_secs(self.max)
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::stats::{AccessKind, AccessStat};

    fn fake_report() -> RunReport {
        RunReport {
            makespan_secs: 10.0,
            total_tasks: 100,
            executed_tasks: 100,
            failed_tasks: 0,
            claim_races_lost: 3,
            dbms_total_secs: 2.0,
            dbms_max_node_secs: 0.8,
            access_stats: vec![(
                AccessKind::GetReadyTasks,
                AccessStat { count: 100, total_secs: 1.2, min_secs: 0.001, max_secs: 0.1 },
            )],
            db_bytes: 4096,
            supervisor_failovers: 0,
        }
    }

    #[test]
    fn report_rendering_contains_key_figures() {
        let s = format_report("test", &fake_report());
        assert!(s.contains("makespan 10.00s"));
        assert!(s.contains("getREADYtasks"));
        assert!(s.contains("60.0%")); // 1.2 / 2.0
        let j = report_json("x", &fake_report()).to_string();
        assert!(j.contains("\"makespan_secs\":10"));
        assert!(j.contains("getREADYtasks"));
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10us .. 10ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(h.mean() > 0.0);
        assert!(h.summary().contains("n=1000"));
    }

    #[test]
    fn histogram_extremes() {
        let mut h = Histogram::new();
        h.record(1e-9);
        h.record(120.0);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        // quantiles never escape the observed range
        assert!(h.quantile(1.0) <= h.max());
        assert!(h.quantile(0.0) >= h.min());
    }

    #[test]
    fn histogram_bucket0_quantile_reports_true_min() {
        let mut h = Histogram::new();
        h.record(2e-7); // sub-microsecond: lands in bucket 0
        h.record(4e-7);
        assert!((h.quantile(0.5) - 4e-7).abs() < 1e-12, "p50 {}", h.quantile(0.5));
        assert!(h.quantile(0.0) >= h.min());
        assert!(h.summary().contains("n=2"));
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(!h.summary().contains("inf"));
    }

    #[test]
    fn merge_equals_combined_recording() {
        let xs: Vec<f64> = (1..=500).map(|i| i as f64 * 7.3e-6).collect();
        let ys: Vec<f64> = (1..=300).map(|i| i as f64 * 1.1e-4).collect();
        let (mut a, mut b, mut both) = (Histogram::new(), Histogram::new(), Histogram::new());
        for &x in &xs {
            a.record(x);
            both.record(x);
        }
        for &y in &ys {
            b.record(y);
            both.record(y);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert!((a.mean() - both.mean()).abs() < 1e-12);
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), both.quantile(q), "quantile {q} diverged");
        }
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Histogram::new();
        a.record(3e-4);
        let before = a.summary();
        a.merge(&Histogram::new());
        assert_eq!(a.summary(), before);
        let mut e = Histogram::new();
        e.merge(&a);
        assert_eq!(e.summary(), before);
    }

    #[test]
    fn from_parts_roundtrip() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 5e-6);
        }
        let rebuilt = Histogram::from_parts(h.buckets.clone(), h.sum, h.min, h.max);
        assert_eq!(rebuilt.count(), h.count());
        assert_eq!(rebuilt.quantile(0.5), h.quantile(0.5));
        assert_eq!(rebuilt.min(), h.min());
        let empty = Histogram::from_parts(vec![0; Histogram::BUCKETS], 0.0, 123.0, 456.0);
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.max(), 0.0);
    }
}
